"""End-to-end driver 2: the paper's char-LM scaling experiment (Fig. 5).

LSTM-with-projection on a synthetic PTB-like 50-char corpus, orthogonal char
embeddings per the paper's Methods, NL-ADC'd gates, BPC metric.

    PYTHONPATH=src python examples/ptb_char_lm.py [--bits 5] [--steps 300]
"""

import argparse
import sys

sys.path.insert(0, ".")

from benchmarks.fig5c_ptb import _spec, train_eval_bpc  # noqa: E402
from repro.data.pipeline import CharCorpus              # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=5)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--proj", type=int, default=64)
    args = ap.parse_args()

    corpus = CharCorpus(seq_len=128, batch=8, corpus_len=120_000)
    print("[ptb] float baseline ...")
    bpc_f = train_eval_bpc(
        _spec(args.bits, "exact", enabled=False, hidden=args.hidden,
              proj=args.proj), corpus, steps=args.steps)
    print(f"[ptb] float BPC: {bpc_f:.3f}")
    print(f"[ptb] {args.bits}-bit NL-ADC noise-aware ...")
    bpc_q = train_eval_bpc(
        _spec(args.bits, "train", hidden=args.hidden, proj=args.proj),
        corpus, steps=args.steps,
        eval_spec=_spec(args.bits, "infer", hidden=args.hidden,
                        proj=args.proj))
    print(f"[ptb] {args.bits}-bit BPC: {bpc_q:.3f} "
          f"(delta {bpc_q - bpc_f:+.3f}; paper: 1.334 -> 1.349 at 5 bits)")


if __name__ == "__main__":
    main()
