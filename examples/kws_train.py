"""End-to-end driver 1: the paper's KWS experiment (Fig. 4).

Trains the 32-hidden-unit analog LSTM (all four gates + cell tanh through
the 5-bit NL-ADC, weights on the simulated 72x128 crossbar) with
hardware-aware training (Alg. 1), then evaluates under write+read noise —
the offline synthetic GSCD substitute.

    PYTHONPATH=src python examples/kws_train.py [--bits 5] [--epochs 8]
"""

import argparse
import sys

sys.path.insert(0, ".")

from benchmarks.fig4d_kws import _make, train_eval  # noqa: E402
from repro.data.pipeline import SyntheticKWS        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=5)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--train", type=int, default=2048)
    args = ap.parse_args()

    data = SyntheticKWS(seed=0).splits(args.train, 512)
    print(f"[kws] float baseline ...")
    acc_f, _ = train_eval(_make(args.bits, "exact", enabled=False), data,
                          epochs=args.epochs)
    print(f"[kws] float accuracy: {acc_f:.3f}")
    print(f"[kws] {args.bits}-bit NL-ADC + noise-aware training ...")
    acc_q, sd = train_eval(_make(args.bits, "train"), data,
                           epochs=args.epochs,
                           eval_spec=_make(args.bits, "infer"))
    print(f"[kws] {args.bits}-bit noisy-chip accuracy: "
          f"{acc_q:.3f} +/- {sd:.3f}")
    print(f"[kws] delta to float: {acc_f - acc_q:+.3f} "
          "(paper: 91.6% -> 88.5% at 5 bits on real GSCD)")


if __name__ == "__main__":
    main()
