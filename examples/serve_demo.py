"""End-to-end driver 3: batched serving of an assigned LM architecture.

Spins up the continuous-batching engine on a reduced qwen2.5-3b (NL-ADC'd
SwiGLU gates), submits a wave of requests, streams tokens.

    PYTHONPATH=src python examples/serve_demo.py [--arch qwen2.5-3b]
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.nn.model import build
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    print(f"[serve] building {cfg.name} ({cfg.family}, NL-ADC "
          f"{cfg.analog.adc_bits}-bit on {cfg.hidden_act})")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=4, max_len=96)

    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=int(rng.integers(4, 16))).astype(np.int32)
        r = Request(uid=uid, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        engine.submit(r)

    t0 = time.time()
    n = 0
    while engine.queue or not all(engine.slot_free):
        out = engine.step()
        n += len(out)
        for uid, tok in sorted(out.items()):
            print(f"  req{uid} -> {tok}")
    dt = time.time() - t0
    print(f"[serve] {len(reqs)} requests, {n} tokens in {dt:.1f}s "
          f"({n / max(dt, 1e-9):.1f} tok/s, CPU smoke config)")
    for r in reqs:
        print(f"  req{r.uid}: prompt {list(r.prompt)} -> {r.generated}")


if __name__ == "__main__":
    main()
