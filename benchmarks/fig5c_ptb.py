"""Fig. 5c: char-LM BPC vs NL-ADC resolution (PTB gated -> synthetic corpus).

Validates the paper's relative claim: BPC(float) <= BPC(5b) <= BPC(4b) <=
BPC(3b) with a small 5-bit delta.  The model is the paper's LSTM-with-
projection scaled to CPU budget (hidden 256 proj 64 for quick mode; the
full 2016/504 model is exercised shape-wise by the unit tests).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog_layer import AnalogConfig
from repro.data.pipeline import CharCorpus
from repro.nn import lstm as NN
from repro.train import optim


def _spec(bits, mode, enabled=True, hidden=256, proj=64):
    return NN.LSTMSpec(
        n_in=128, n_hidden=hidden, n_proj=proj,
        analog=AnalogConfig(enabled=enabled, adc_bits=bits, input_bits=bits,
                            mode=mode))


def train_eval_bpc(spec, corpus, *, steps=120, lr=2e-3, seed=0,
                   eval_spec=None):
    emb = jnp.asarray(corpus.embeddings())          # (50, 128) orthogonal
    acts = NN.make_gate_acts(spec.analog)
    params = NN.classifier_init(jax.random.PRNGKey(seed), spec, 50)
    opt = optim.Adam(lr=lr)
    state = opt.init(params)

    def loss_fn(p, toks, labels, key):
        xs = emb[toks]                              # (B, T, 128)
        logits = NN.classifier_apply(p, xs, spec, acts, key=key,
                                     all_steps=True)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        return jnp.mean(nll)

    @jax.jit
    def step(p, s, toks, labels, key):
        l, g = jax.value_and_grad(loss_fn)(p, toks, labels, key)
        p, s = opt.update(g, s, p)
        return p, s, l

    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        b = corpus.batch_at(i)
        key, k = jax.random.split(key)
        params, state, _ = step(params, state, jnp.asarray(b["tokens"]),
                                jnp.asarray(b["labels"]), k)

    espec = eval_spec or spec
    eacts = NN.make_gate_acts(espec.analog)

    @jax.jit
    def eval_nll(p, toks, labels, key):
        xs = emb[toks]
        logits = NN.classifier_apply(p, xs, espec, eacts, key=key,
                                     all_steps=True)
        logp = jax.nn.log_softmax(logits)
        return jnp.mean(
            -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0])

    nlls = []
    for i in range(4):
        b = corpus.batch_at(10_000 + i)
        nlls.append(float(eval_nll(params, jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["labels"]),
                                   jax.random.PRNGKey(500 + i))))
    return float(np.mean(nlls)) / np.log(2.0)       # BPC


def run(quick=True):
    steps = 80 if quick else 400
    seq = 64 if quick else 128
    corpus = CharCorpus(seq_len=seq, batch=16, corpus_len=60_000)
    print("=== Fig. 5c: char-LM BPC vs NL-ADC bits (synthetic corpus) ===")
    t0 = time.time()
    rows = {}
    bpc = train_eval_bpc(_spec(5, "exact", enabled=False), corpus,
                         steps=steps)
    rows["float"] = bpc
    print(f"float baseline BPC: {bpc:.3f}")
    for bits in (5, 4, 3):
        bpc = train_eval_bpc(_spec(bits, "train"), corpus, steps=steps,
                             eval_spec=_spec(bits, "infer"))
        rows[f"{bits}b"] = bpc
        print(f"{bits}-bit NL-ADC (noise-aware train, noisy infer) BPC: "
              f"{bpc:.3f}")
    print(f"(paper: 1.334 fp / 1.349 5b / 1.367 4b / 1.428 3b on real PTB; "
          f"{time.time() - t0:.0f}s)")
    ok = rows["float"] <= rows["5b"] + 0.05 and rows["5b"] <= rows["3b"] + 0.05
    print("ordering float <= 5b <= 3b:", "OK" if ok else "VIOLATED")
    return rows


if __name__ == "__main__":
    run(quick=False)
