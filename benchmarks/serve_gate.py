"""Serving-throughput CI gate: re-run the offline burst, diff the baseline.

    PYTHONPATH=src python -m benchmarks.serve_gate [--min-speedup F] \
        [--tol-speedup F]

Runs ``benchmarks.serve_throughput`` on the quick burst and fails — exit
code 1 — when the throughput path regresses against the committed
``BENCH_serve.json``:

* **bitwise parity** is asserted twice: the sweep itself aborts if any
  cell's token streams diverge from the scan cell, and the gate diffs
  every cell's streams + token totals EXACTLY against the recorded
  baseline (exact-mode smoke config on the ref backend — deterministic,
  so a single changed token means the serving numerics moved);
* the ``bucketed_pack`` speedup over the scan cell must stay above
  ``--min-speedup`` (hard floor, default 1.5x) AND above the baseline
  ratio scaled by ``--tol-speedup`` — the ratio is scan-normalized on
  the same machine in the same process, so it gates compile-amortization
  and packing without ever diffing wall-clock seconds across machines;
* the ``bucketed_pack_obs`` cell (full ``repro.obs`` tracing + metrics +
  energy counters) must hold >= ``--min-obs-ratio`` (default 0.95) of
  the plain bucketed cell's tokens/s — observability stays under 5%
  throughput overhead, measured same-run/same-machine.

Raw ``tokens_per_s`` is recorded in the baseline but never diffed.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks import serve_throughput

BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")


def compare(results: dict, baseline: dict, min_speedup: float,
            tol_speedup: float, min_obs_ratio: float = 0.95) -> list:
    failures = []
    want_cells, got_cells = baseline["cells"], results["cells"]
    for key in sorted(set(want_cells) ^ set(got_cells)):
        side = "baseline" if key in want_cells else "sweep"
        failures.append(f"cell {key}: only present in the {side}; "
                        "re-record BENCH_serve.json")
    for key in sorted(set(want_cells) & set(got_cells)):
        want, got = want_cells[key], got_cells[key]
        if got["streams"] != want["streams"]:
            bad = sorted(uid for uid in want["streams"]
                         if got["streams"].get(uid) != want["streams"][uid])
            failures.append(
                f"{key}: token streams changed vs the recorded baseline "
                f"(uids {bad}) — the serving numerics moved")
        if got["tokens_total"] != want["tokens_total"]:
            failures.append(
                f"{key}: {got['tokens_total']} tokens vs baseline "
                f"{want['tokens_total']}")
        if got["buckets"] != want["buckets"]:
            failures.append(
                f"{key}: prefill buckets {got['buckets']} vs baseline "
                f"{want['buckets']} — the bucket ladder changed")

    got_ratio = results["speedup"].get("bucketed_pack", 0.0)
    want_ratio = baseline["speedup"].get("bucketed_pack", 0.0)
    floor = max(min_speedup, want_ratio * tol_speedup)
    if got_ratio < floor:
        failures.append(
            f"bucketed_pack speedup {got_ratio:.2f}x vs scan, below "
            f"{floor:.2f}x (hard floor {min_speedup:.2f}x, baseline "
            f"{want_ratio:.2f}x scaled by {tol_speedup:.2f}) — AOT bucket "
            "amortization or packing regressed")

    obs_ratio = results.get("obs_overhead", 0.0)
    if obs_ratio < min_obs_ratio:
        failures.append(
            f"obs overhead: bucketed_pack_obs runs at {obs_ratio:.3f}x of "
            f"bucketed_pack, below {min_obs_ratio:.2f}x — observability "
            "instrumentation costs more than its throughput budget")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="hard floor on the bucketed_pack/scan tokens/s "
                         "ratio, machine-independent")
    ap.add_argument("--tol-speedup", type=float, default=0.25,
                    help="fraction of the baseline ratio that must be "
                         "retained (ratios vary with CI load; the hard "
                         "floor is the real gate)")
    ap.add_argument("--min-obs-ratio", type=float, default=0.95,
                    help="floor on bucketed_pack_obs/bucketed_pack "
                         "tokens/s — full observability must cost < 5%%")
    args = ap.parse_args()

    with open(BASELINE) as f:
        baseline = json.load(f)
    if not baseline.get("quick", True):
        print("[serve-gate] note: baseline was recorded with quick=False; "
              "the gate compares a quick run against it")
    results = serve_throughput.run(quick=True)

    failures = compare(results, baseline, args.min_speedup,
                       args.tol_speedup, args.min_obs_ratio)
    if failures:
        print(f"\n[serve-gate] FAIL — {len(failures)} deltas over "
              "tolerance vs benchmarks/BENCH_serve.json:")
        for fail in failures:
            print("  " + fail)
        print("If the shift is intentional, re-record the (quick) "
              "baseline: rm benchmarks/BENCH_serve.json && PYTHONPATH=src "
              "python -m benchmarks.run --only serve_throughput")
        return 1
    print("\n[serve-gate] OK — offline serving parity bitwise, speedup "
          f"{results['speedup']['bucketed_pack']:.1f}x within tolerance of "
          f"BENCH_serve.json, obs overhead {results['obs_overhead']:.3f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
