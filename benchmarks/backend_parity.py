"""ref-vs-pallas backend parity + throughput sweep.

Times the analog primitives on both backends over model-shaped workloads
and checks quantization-exact agreement while it's at it.  Writes the
result to ``benchmarks/BENCH_backend.json``.

NOTE on the numbers: off-TPU the Pallas kernels run in **interpret mode**
(the correctness-validation path, orders of magnitude slower than compiled
kernels) — CPU results benchmark the *plumbing*, not the fusion win.  The
recorded baseline is marked ``device: cpu-interpret`` accordingly; re-run
on a TPU host for the real comparison.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as BK
from repro.core.nladc import NLADC, build_ramp
from repro.kernels import interpret_mode

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_backend.json")


def _time(fn, *args, repeat=3):
    jax.block_until_ready(fn(*args))          # compile + warm
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _bench_matmul(results, quick):
    shapes = [(256, 512, 512)] if quick else [(256, 512, 512),
                                              (1024, 1024, 1024)]
    ramp = build_ramp("swish", 5)
    adc = NLADC(ramp)
    rng = np.random.default_rng(0)
    for (m, k, n) in shapes:
        x = jnp.asarray(rng.normal(0, 0.4, (m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, 0.2, (k, n)).astype(np.float32))
        row = {}
        outs = {}
        for be in ("ref", "pallas"):
            bk = BK.get_backend(be)
            f = jax.jit(lambda x_, w_, bk=bk: bk.matmul_nladc(x_, w_, adc))
            row[be + "_s"] = _time(f, x, w)
            outs[be] = f(x, w)
        row["max_abs_diff"] = float(jnp.max(jnp.abs(outs["ref"]
                                                    - outs["pallas"])))
        row["quantization_exact"] = bool(row["max_abs_diff"] < ramp.lsb / 2)
        results[f"matmul_nladc_{m}x{k}x{n}"] = row


def _bench_lstm(results, quick):
    sig, tnh = NLADC(build_ramp("sigmoid", 5)), NLADC(build_ramp("tanh", 5))
    rng = np.random.default_rng(1)
    b, h = (64, 512) if quick else (256, 2016)
    g = jnp.asarray(rng.normal(0, 1.5, (b, 4 * h)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 0.5, (b, h)).astype(np.float32))
    row = {}
    outs = {}
    for be in ("ref", "pallas"):
        bk = BK.get_backend(be)
        f = jax.jit(lambda g_, c_, bk=bk: bk.lstm_gates(g_, c_, sig, tnh))
        row[be + "_s"] = _time(f, g, c)
        outs[be] = f(g, c)
    row["max_abs_diff"] = max(
        float(jnp.max(jnp.abs(a - b2)))
        for a, b2 in zip(outs["ref"], outs["pallas"]))
    row["quantization_exact"] = bool(
        row["max_abs_diff"] < build_ramp("sigmoid", 5).lsb / 2)
    results[f"lstm_gates_{b}x{h}"] = row


def _bench_flash_decode(results, quick):
    rng = np.random.default_rng(2)
    b, hq, hkv, d, s = (4, 8, 2, 64, 512) if quick else (16, 16, 4, 128,
                                                         4096)
    q = jnp.asarray(rng.normal(0, 1, (b, hq, d)).astype(np.float32))
    k8 = jnp.asarray(rng.integers(-127, 128, (b, s, hkv, d)), jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 128, (b, s, hkv, d)), jnp.int8)
    ks = jnp.asarray(rng.uniform(1e-3, 2e-2, (b, s, hkv)).astype(np.float32))
    vs = jnp.asarray(rng.uniform(1e-3, 2e-2, (b, s, hkv)).astype(np.float32))
    ln = jnp.asarray(rng.integers(1, s, (b,)), jnp.int32)
    row = {}
    outs = {}
    for be in ("ref", "pallas"):
        bk = BK.get_backend(be)
        f = jax.jit(lambda *a, bk=bk: bk.decode_attention_int8(*a))
        row[be + "_s"] = _time(f, q, k8, ks, v8, vs, ln)
        outs[be] = f(q, k8, ks, v8, vs, ln)
    row["max_abs_diff"] = float(jnp.max(jnp.abs(outs["ref"]
                                                - outs["pallas"])))
    results[f"flash_decode_int8_b{b}_s{s}"] = row


def run(quick: bool = True) -> dict:
    results = {
        "device": ("cpu-interpret" if interpret_mode()
                   else jax.default_backend()),
        "note": ("pallas timings are interpret-mode (correctness path, not "
                 "representative of compiled-kernel throughput)"
                 if interpret_mode() else "compiled kernels"),
    }
    _bench_matmul(results, quick)
    _bench_lstm(results, quick)
    _bench_flash_decode(results, quick)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    for name, row in results.items():
        if isinstance(row, dict):
            print(f"  {name}: ref {row.get('ref_s', 0)*1e3:.2f} ms | "
                  f"pallas {row.get('pallas_s', 0)*1e3:.2f} ms | "
                  f"maxdiff {row.get('max_abs_diff'):.2e}")
    print(f"  -> {OUT_PATH}")
    return results
