"""Re-calibration schedule sweep: KWS accuracy over device lifetime.

The lifecycle claim (NEON-style): an analog NL-ADC deployment drifts out of
spec over shelf/serving time, and periodic **one-point re-calibration** of
the ramp columns (Supp. S9, realized by ``repro.serve.lifecycle``) recovers
most of the lost accuracy without reprogramming the weight crossbars.

This sweep trains one KWS LSTM under the ``paper`` device (Alg. 1), then
replays the same aging timeline twice through a :class:`RecalScheduler` —
once with re-calibration disabled (INL threshold = inf) and once with the
default policy — recording the age → INL → accuracy trace for each.  The
weight crossbars age identically in both runs (TilePlan-keyed per-tile
draws, deterministic in the device seed); only the ADC periphery treatment
differs, isolating exactly what the scheduler buys.

Writes ``benchmarks/BENCH_recal.json`` as the recorded baseline.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog_layer import AnalogConfig
from repro.core.device import get_device
from repro.nn import lstm as NN
from repro.serve.lifecycle import RecalPolicy, RecalScheduler

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_recal.json")

# One probe per aging step; each step adds Δt so the trace spans the Supp.
# S13 measurement window (60 s .. 5e5 s) in a handful of probes.
AGE_STEP_S = 5e4
N_STEPS = 10
RECAL_INL_LSB = 0.4


def _timeline(params, data, base_dev, recalibrate: bool):
    """Replay the aging timeline; returns the scheduler's event trace."""
    (_, _), (xte, yte) = data
    spec = NN.LSTMSpec(
        n_in=40, n_hidden=32,
        analog=AnalogConfig(enabled=True, adc_bits=5, input_bits=5,
                            mode="infer", device=base_dev))
    acts = NN.make_gate_acts(spec.analog)
    act_map = {"sigmoid": acts[0], "tanh": acts[1]}
    policy = RecalPolicy(
        age_per_step_s=AGE_STEP_S, check_every=1,
        inl_threshold_lsb=RECAL_INL_LSB if recalibrate else float("inf"))

    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    def accuracy():
        aged_dev = base_dev.with_drift(max(sched.age_s, 0.0)) \
            if sched.age_s > 0 else base_dev
        aged = aged_dev.age_params(params) if aged_dev.has_build_stage \
            else params
        # thresholds live inside the activations (redeployed by the
        # scheduler); re-jit per probe by closing over the current chip
        pred = jax.jit(lambda p, xb: jnp.argmax(
            NN.classifier_apply(p, xb, spec, acts), -1))(aged, xte_j)
        return float(jnp.mean(pred == yte_j))

    sched = RecalScheduler(base_dev, act_map, policy,
                           accuracy_probe=accuracy)
    for _ in range(N_STEPS):
        sched.tick()
    return sched


def run(quick=True):
    from benchmarks.s13_drift import train_kws
    from repro.data.pipeline import SyntheticKWS

    n_train = 512 if quick else 2048
    epochs = 3 if quick else 10
    data = SyntheticKWS(seed=0).splits(n_train, 256)
    print("=== recal schedule: training KWS under `paper` (Alg. 1) ===")
    params = train_kws(data, epochs, get_device("paper"))

    base = get_device("paper-infer")
    out = {}
    for label, recal in (("no-recal", False), ("recal", True)):
        sched = _timeline(params, data, base, recal)
        trace = [{"age_s": ev["age_s"], "inl_lsb": ev["inl_lsb"],
                  "accuracy": round(ev["accuracy"], 4),
                  "recalibrated": ev["recalibrated"],
                  **({"inl_after_lsb": ev["inl_after_lsb"],
                      "accuracy_recovered": round(
                          ev["accuracy_recovered"], 4)}
                     if ev["recalibrated"] else {})}
                 for ev in sched.events]
        out[label] = {"n_recals": sched.n_recals, "trace": trace}
        last = trace[-1]
        print(f"  {label:9} n_recals={sched.n_recals:2d}  "
              f"final age {last['age_s']:.0e}s  "
              f"INL {last.get('inl_after_lsb', last['inl_lsb']):.3f} LSB  "
              f"acc {last.get('accuracy_recovered', last['accuracy']):.3f}")

    # The mechanism check: re-calibration keeps deployed INL strictly below
    # the free-running ramp's at end of life.
    final_inl_recal = min(e.get("inl_after_lsb", e["inl_lsb"])
                          for e in out["recal"]["trace"][-2:])
    final_inl_free = out["no-recal"]["trace"][-1]["inl_lsb"]
    assert final_inl_recal < final_inl_free, (final_inl_recal,
                                              final_inl_free)

    results = {"quick": quick, "age_step_s": AGE_STEP_S, "n_steps": N_STEPS,
               "inl_threshold_lsb": RECAL_INL_LSB, "timelines": out}
    if not quick or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"  baseline written to {OUT_PATH}")
    return results


if __name__ == "__main__":
    run(quick=False)
