"""Tab. S6-S9: NLP (PTB LSTM) macro-level costs, ours vs conventional k=1/8."""

from repro.core import hwcost as HW

PAPER_S9 = {  # (tput TOPS, TOPS/W, TOPS/mm2)
    "5b": (79.14, 60.77, 363.2),
    "4b": (157.06, 121.62, 722.34),
    "3b": (309.36, 243.36, 1425.81),
    "conv_k1": (0.62, 55.11, 1.35),
    "conv_k8": (4.8, 55.11, 10.21),
}


def run(quick=True):
    print("=== Tab. S9: NLP macro metrics (model | paper) ===")
    rows = {
        "5b": HW.nlp_macro(5), "4b": HW.nlp_macro(4), "3b": HW.nlp_macro(3),
        "conv_k1": HW.nlp_macro(5, conventional=True, k_procs=1),
        "conv_k8": HW.nlp_macro(5, conventional=True, k_procs=8),
    }
    out = {}
    for tag, m in rows.items():
        p = PAPER_S9[tag]
        print(f"  {tag:8} tput {m.throughput_tops:7.2f}|{p[0]:7.2f} TOPS  "
              f"eff {m.tops_per_w:6.2f}|{p[1]:6.2f} TOPS/W  "
              f"ae {m.tops_per_mm2:8.2f}|{p[2]:8.2f} TOPS/mm2")
        out[tag] = dict(tops=m.throughput_tops, tops_per_w=m.tops_per_w)
    adv_t = rows["5b"].throughput_tops / rows["conv_k8"].throughput_tops
    adv_a = rows["5b"].tops_per_mm2 / rows["conv_k8"].tops_per_mm2
    print(f"  5b vs conv(k=8): {adv_t:.1f}x throughput (paper ~16x), "
          f"{adv_a:.1f}x area-eff (paper ~42x, Tab. S9 note)")
    return out


if __name__ == "__main__":
    run()
