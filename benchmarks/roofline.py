"""Roofline terms from compiled dry-run artifacts (TPU v5e model).

    compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s)
    memory term     = HLO_bytes / (chips x 819e9 B/s)
    collective term = collective_bytes / (chips x 50e9 B/s per ICI link)

``cost_analysis()`` supplies FLOPs / bytes-accessed.  Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text, build an
instruction-name -> shape map, and sum the *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(operand bytes = what actually crosses the links for AR/RS; for AG/A2A the
result is the moved volume — we take max(operand, result) per op as the
conservative wire estimate).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([\w\-]+)"
)
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_COMPUTATION_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")


def convert_bytes(hlo_text: str) -> int:
    """Materialized dtype-convert bytes (operands+results).

    The CPU backend lowers bf16 dots as convert->f32-dot, materializing
    f32 copies the TPU MXU never creates (native bf16 operands).  The
    adjusted memory term subtracts these (documented optimistic bound:
    genuine storage-dtype conversions are subtracted too).

    Only counts converts that are *materialized* — i.e. standalone
    instructions in non-fusion computations (ENTRY / loop bodies) or
    fusion ops that wrap a lone convert.  Converts inside larger fusion
    bodies are already invisible to bytes-accessed and must not be
    subtracted.
    """
    total = 0
    in_fusion_comp = False
    for line in hlo_text.splitlines():
        cm = _COMPUTATION_RE.match(line)
        if cm:
            name = cm.group(2)
            in_fusion_comp = ("fused" in name or "wrapped" in name) \
                and not cm.group(1)
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        is_conv = (op == "convert") or (op == "fusion"
                                        and "wrapped_convert" in line)
        if not is_conv or (in_fusion_comp and op == "convert"):
            continue
        result = shape_bytes(m.group(2))
        # bytes-accessed charges operand+result; for bf16<->f32 that is
        # ~1.5x the f32 side.
        total += int(result * 1.5)
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum data volume of collective ops in optimized HLO text."""
    shapes: Dict[str, str] = {}
    pending: List[Tuple[str, str, str]] = []  # (kind, result_shape, args)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = shape_str
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # the matching -start already counted
        args = line[line.find("(") + 1: line.rfind(")")]
        pending.append((kind, shape_str, args))

    counts: Dict[str, int] = {}
    vol: Dict[str, int] = {}
    arg_re = re.compile(r"%?([\w.\-]+)")
    for kind, result_shape, args in pending:
        operand_bytes = 0
        for a in args.split(","):
            a = a.strip()
            m = arg_re.match(a)
            if m and m.group(1) in shapes:
                operand_bytes += shape_bytes(shapes[m.group(1)])
        result_bytes = shape_bytes(result_shape)
        # Ring-algorithm wire volume per participant:
        #   all-reduce      = 2x operand   (reduce-scatter + all-gather)
        #   all-gather      = result       (each chip receives the rest)
        #   reduce-scatter  = operand
        #   all-to-all      = operand
        #   collective-perm = operand
        if kind == "all-reduce":
            moved = 2 * max(operand_bytes, result_bytes)
        elif kind == "all-gather":
            moved = max(operand_bytes, result_bytes)
        else:
            moved = max(operand_bytes, result_bytes if kind == "all-to-all"
                        else operand_bytes)
        counts[kind] = counts.get(kind, 0) + 1
        vol[kind] = vol.get(kind, 0) + moved
    return CollectiveStats(counts=counts, bytes_by_kind=vol)


def cost_dict(compiled) -> Dict[str, float]:
    """Flatten compiled.cost_analysis() across backends/jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "optimal_seconds"):
        if ca and k in ca:
            out[k] = float(ca[k])
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, int]
    collective_bytes_by_kind: Dict[str, int]
    model_flops: float                 # 6*N*D (or 6*N_active*D for MoE)
    per_device_peak_memory: Optional[float] = None
    # bytes minus CPU-backend convert artifacts (TPU-representative bound)
    hlo_bytes_adjusted: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_memory_adjusted(self) -> float:
        b = self.hlo_bytes_adjusted
        return (b if b is not None else self.hlo_bytes) / (
            self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute,
                 "memory": self.t_memory_adjusted,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline: T_comp / max(all terms).

        == 1.0 when compute-bound; < 1 when memory/collective dominates.
        Uses the adjusted (TPU-representative) memory term.
        """
        t = max(self.t_compute, self.t_memory_adjusted, self.t_collective)
        return self.t_compute / t if t > 0 else 0.0

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_memory_adjusted=self.t_memory_adjusted,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops_for(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward-only) per step."""
    n = cfg.n_active_params() if cfg.family == "moe" else cfg.n_params()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def save(roof: Roofline, path: str):
    with open(path, "w") as f:
        json.dump(roof.to_json(), f, indent=2)
