"""Threshold-bank sweep: deployed INL and KWS accuracy vs bank count.

One physical ramp generator serves the comparator bank of ONE crossbar
col-tile, so a deployment's threshold layout is ``(n_col_tiles, P)`` —
more banks mean more independently-programmed (and independently
drifting) ramp columns.  This sweep measures what that granularity costs
and buys:

* **INL vs bank count** — mean/worst deployed INL across the bank for
  n_banks = 1/2/4/8 under each build-stage preset.  The mean is flat (each
  bank is the same process), the WORST bank degrades with count — that
  worst column is what per-bank re-calibration targets.
* **accuracy vs bank count** — the paper's KWS LSTM (Alg. 1-trained under
  ``paper``) evaluated in infer mode with ``bank_cols`` shrinking so the
  H=32 hidden dim spans 1/2/4/8 col-tiles, on ref AND pallas-interpret.

Writes ``benchmarks/BENCH_bank.json`` as the recorded baseline.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.device import get_device
from repro.core.nladc import build_ramp, inl_lsb

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_bank.json")

BANK_COUNTS = (1, 2, 4, 8)
PRESETS = ("paper-infer", "aged-1day", "stressed")
HIDDEN = 32     # the KWS LSTM hidden size; bank_cols = HIDDEN // n_banks


def _inl_sweep():
    out = {}
    ramp = build_ramp("tanh", 5)
    for preset in PRESETS:
        dev = get_device(preset)
        rows = {}
        for n in BANK_COUNTS:
            inls = [inl_lsb(r, ramp)[0]
                    for r in dev.deploy_ramp_bank(ramp, n)]
            rows[f"B{n}"] = {"mean": round(float(np.mean(inls)), 4),
                             "worst": round(float(np.max(inls)), 4)}
        out[preset] = rows
        print(f"  {preset:12} " + "  ".join(
            f"B{n}: {rows[f'B{n}']['mean']:.3f}/{rows[f'B{n}']['worst']:.3f}"
            for n in BANK_COUNTS))
    return out


def _accuracy_sweep(quick: bool):
    from benchmarks.device_sweep import _accuracy_under
    from benchmarks.s13_drift import train_kws
    from repro.data.pipeline import SyntheticKWS

    n_train = 512 if quick else 2048
    epochs = 3 if quick else 10
    data = SyntheticKWS(seed=0).splits(n_train, 256)
    params = train_kws(data, epochs, get_device("paper"))
    out = {}
    for preset in ("paper-infer", "aged-1day"):
        dev = get_device(preset)
        rows = {}
        for n in BANK_COUNTS:
            bank_cols = 0 if n == 1 else HIDDEN // n
            for be in ("ref", "pallas"):
                rows[f"B{n}-{be}"] = round(
                    _accuracy_under(params, data, dev, tiled=True,
                                    bank_cols=bank_cols, backend=be), 4)
        out[preset] = rows
        print(f"  {preset:12} " + "  ".join(
            f"{k}:{v:.3f}" for k, v in rows.items()))
    return out


def run(quick=True):
    print("=== bank sweep: deployed INL vs bank count ===")
    inl = _inl_sweep()
    print("=== bank sweep: KWS accuracy vs bank count (ref + pallas) ===")
    acc = _accuracy_sweep(quick)
    # invariant: the worst bank is never better than the mean, and banked
    # deployment keeps the fresh chip usable
    for preset in PRESETS:
        for n in BANK_COUNTS:
            cell = inl[preset][f"B{n}"]
            assert cell["worst"] >= cell["mean"] - 1e-9
    assert acc["paper-infer"]["B4-ref"] >= 0.5
    results = {"quick": quick, "hidden": HIDDEN,
               "bank_counts": list(BANK_COUNTS),
               "ramp_inl_lsb": inl, "kws_accuracy": acc}
    if not quick or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"  baseline written to {OUT_PATH}")
    return results


if __name__ == "__main__":
    run(quick=False)
