"""Tab. 1 / Fig. 4e: our LSTM implementation vs published LSTM accelerators
(normalized area efficiency at 1 GHz / 16 nm)."""

from repro.core import hwcost as HW
from repro.core.hwcost import TAB1_PUBLISHED


def run(quick=True):
    ours_kws = HW.kws_system(5)
    ours_nlp = HW.nlp_system(5)
    print("=== Tab. 1: LSTM accelerator comparison (system level) ===")
    print(f"  {'design':22} {'TOPS/W':>8} {'norm TOPS/mm2':>14}")
    print(f"  {'this work (KWS 5b)':22} {ours_kws.tops_per_w:8.2f} "
          f"{ours_kws.tops_per_mm2:14.2f}")
    print(f"  {'this work (NLP 5b)':22} {ours_nlp.tops_per_w:8.2f} "
          f"{ours_nlp.tops_per_mm2:14.2f}")
    best_eff = best_ae = 0.0
    for name, d in TAB1_PUBLISHED.items():
        print(f"  {name:22} {d['tops_per_w']:8.2f} {d['norm_ae']:14.2f}")
        best_eff = max(best_eff, d["tops_per_w"])
        best_ae = max(best_ae, d["norm_ae"])
    adv_eff = ours_kws.tops_per_w / best_eff
    adv_ae = ours_kws.tops_per_mm2 / best_ae
    print(f"  advantage vs best published: {adv_eff:.1f}x energy-eff "
          f"(paper ~4.5x), {adv_ae:.1f}x norm area-eff (paper ~9.9x)")
    return {"ours_eff": ours_kws.tops_per_w, "adv_eff": adv_eff,
            "adv_ae": adv_ae}


if __name__ == "__main__":
    run()
