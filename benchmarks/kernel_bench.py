"""Kernel microbenchmarks (interpret-mode correctness + jnp-path wall time).

On this CPU container the Pallas kernels execute in interpret mode, so wall
time is NOT the TPU performance signal — the §Roofline/§Perf numbers come
from the compiled dry-run.  This bench (a) re-validates kernels vs oracles
at benchmark shapes, (b) times the pure-jnp reference paths so regressions
in the simulation hot loop are visible.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nladc import build_ramp
from repro import kernels
from repro.kernels import ref


def _time(fn, *args, n=5):
    # warm up exactly once (compile + first run) and reuse the result —
    # jax.block_until_ready handles tuples and single arrays alike
    warm = fn(*args)
    jax.block_until_ready(warm)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(quick=True):
    rng = np.random.default_rng(0)
    ramp = build_ramp("sigmoid", 5)
    out = {}
    shapes = [(512, 1024)] if quick else [(512, 1024), (2048, 4096)]
    print("=== kernel bench (oracle path wall time; interpret correctness) ===")
    for shape in shapes:
        x = jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))
        w = jnp.asarray(rng.normal(0, 0.1,
                                   (shape[1], 512)).astype(np.float32))
        j_nladc = jax.jit(lambda v: ref.nladc(v, ramp))
        j_fused = jax.jit(lambda a, b: ref.fused_matmul_nladc(a, b, ramp))
        us1 = _time(j_nladc, x)
        us2 = _time(j_fused, x, w)
        # interpret-mode correctness at this shape
        got = kernels.nladc(x[:64, :256], ramp)
        np.testing.assert_allclose(got, ref.nladc(x[:64, :256], ramp),
                                   rtol=1e-5, atol=1e-5)
        print(f"  {shape}: nladc {us1:8.1f} us   fused-matmul {us2:8.1f} us "
              f"(jnp ref path, CPU)")
        out[str(shape)] = dict(nladc_us=us1, fused_us=us2)
    return out


if __name__ == "__main__":
    run(quick=False)
