"""Host-device-count scaling sweep for the distribution layer.

For each forced host-device count (``xla_force_host_platform_device_count``
= 1/2/4/8) a subprocess times the smoke-config train step two ways:

* **replicated** — the plain jitted step on one device (the no-dist
  baseline every count is normalized against);
* **sharded**    — the shard_map data-parallel step from
  :func:`repro.launch.steps.make_dp_train_step` with the batch split over
  the ``data`` axis and an explicit psum gradient all-reduce.

Reported as tokens/s.  On the CPU host the forced devices share the same
cores, so this measures *correct scaling plumbing* (the sharded step must
not regress as devices multiply), not real speedup — the dry-run roofline
covers projected hardware numbers.

    PYTHONPATH=src python -m benchmarks.dist_scaling [--full]
"""

from __future__ import annotations

import json
import subprocess
import sys

from repro.subproc import run_in_subprocess

_CHILD = """
    import json, time
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.data.pipeline import SyntheticLM
    from repro.dist import sharding as SH
    from repro.ft.elastic import build_mesh, plan_for_devices
    from repro.launch.steps import (make_dp_train_step, make_optimizer,
                                    make_train_step)
    from repro.nn.model import build

    BATCH, SEQ, STEPS = 8, 64, 3
    cfg = configs.get_smoke("qwen2.5-3b")
    # One optimizer for both paths so the comparison isolates the gradient
    # path (same reasoning as launch/train.py).
    model = build(cfg)
    opt = make_optimizer(cfg)
    train_step = make_train_step(model, opt)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    pipe = SyntheticLM(cfg.vocab, SEQ, BATCH)
    batches = [{k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
               for s in range(STEPS + 1)]

    # Batches are pre-placed *outside* the timed region for both paths, so
    # the replicated-vs-sharded comparison measures the step, not host->
    # device transfer.
    def bench(step_fn, placed):
        p, o = params, opt_state
        p, o, _ = step_fn(p, o, placed[0], 0)             # compile+warmup
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        for s in range(1, STEPS + 1):
            p, o, _ = step_fn(p, o, placed[s], s)
        jax.block_until_ready(p)
        return BATCH * SEQ * STEPS / (time.perf_counter() - t0)

    n = len(jax.devices())
    rep_tps = bench(jax.jit(train_step), batches)

    plan = plan_for_devices(n, global_batch=BATCH, model_parallel=1)
    mesh = build_mesh(plan)
    dp = jax.jit(make_dp_train_step(model, opt, mesh, grad_comm="psum"))
    bsh = SH.shardings_for(SH.batch_specs(batches[0], mesh), mesh)
    placed = [jax.tree.map(jax.device_put, b, bsh) for b in batches]
    jax.block_until_ready(placed)
    shard_tps = bench(dp, placed)

    print(json.dumps({"devices": n, "data_parallel": plan.new_shape["data"],
                      "replicated_tokens_per_s": round(rep_tps, 1),
                      "sharded_tokens_per_s": round(shard_tps, 1)}))
"""


def _sweep_one(devices: int) -> dict:
    try:
        out = run_in_subprocess(_CHILD, devices, timeout=600)
    except subprocess.TimeoutExpired:
        return {"devices": devices, "error": "timeout after 600s"}
    if out.returncode != 0:
        return {"devices": devices, "error": out.stderr[-800:]}
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(quick: bool = True) -> dict:
    counts = (1, 2) if quick else (1, 2, 4, 8)
    rows = [_sweep_one(n) for n in counts]
    for r in rows:
        if "error" in r:
            print(f"  devices={r['devices']}: FAILED {r['error'][:200]}")
            continue
        print(f"  devices={r['devices']} (dp={r['data_parallel']}): "
              f"replicated {r['replicated_tokens_per_s']:9.1f} tok/s   "
              f"sharded {r['sharded_tokens_per_s']:9.1f} tok/s")
    ok = [r for r in rows if "error" not in r]
    assert ok, rows
    return {"rows": rows}


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
