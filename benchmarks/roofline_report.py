"""Aggregate the dry-run JSONs into the §Roofline table (markdown + CSV)."""

import glob
import json
import os

HEADERS = ("arch", "shape", "mesh", "dominant", "t_compute", "t_memory",
           "t_memory_adjusted", "t_collective", "roofline_fraction",
           "useful_flops_ratio", "hlo_flops", "collective_bytes")


def load(results_dir=None):
    import os
    if results_dir is None:
        results_dir = ("results/dryrun_final"
                       if os.path.isdir("results/dryrun_final")
                       else "results/dryrun")
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") != "ok":
            rows.append({"arch": d.get("arch"), "shape": d.get("shape"),
                         "mesh": "2x16x16" if d.get("multi_pod") else "16x16",
                         "error": d.get("error", "?")})
            continue
        rows.append({k: d.get(k) for k in HEADERS})
    return rows


def run(quick=True, results_dir=None):
    rows = load(results_dir)
    # the roofline table is single-pod; multi-pod rows are compile proof
    ok = [r for r in rows if "error" not in r and r["mesh"] == "16x16"]
    n_mp = sum(1 for r in rows if "error" not in r and r["mesh"] != "16x16")
    print(f"=== §Roofline: {len(ok)} single-pod cells "
          f"(+{n_mp} multi-pod compile proofs) ===")
    print(f"{'arch':22}{'shape':13}{'dom':11}{'t_comp':>9}"
          f"{'t_mem':>9}{'t_adj':>9}{'t_coll':>9}{'frac':>7}{'useful':>8}")
    for r in sorted(ok, key=lambda r: (r['arch'], r['shape'])):
        tadj = r.get('t_memory_adjusted') or r['t_memory']
        print(f"{r['arch']:22}{r['shape']:13}"
              f"{r['dominant']:11}{r['t_compute']:9.4f}{r['t_memory']:9.4f}"
              f"{tadj:9.4f}"
              f"{r['t_collective']:9.4f}{r['roofline_fraction']:7.3f}"
              f"{r['useful_flops_ratio']:8.3f}")
    bad = [r for r in rows if "error" in r]
    for r in bad:
        print(f"FAILED: {r}")
    return {"n_ok": len(ok), "n_fail": len(bad)}


if __name__ == "__main__":
    run()
