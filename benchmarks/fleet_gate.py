"""Fleet-orchestration CI gate: re-run the fleet sweep, diff the baseline.

    PYTHONPATH=src python -m benchmarks.fleet_gate [--tol-steps N] \
        [--tol-tokens F]

Runs ``benchmarks.fleet_sweep`` on the quick grid and fails — exit code
1 — when the orchestration regresses against the committed
``BENCH_fleet.json``:

* ``min_accepting_frac`` below the planner's floor for that cell is an
  UNCONDITIONAL failure (the capacity invariant, no tolerance);
* ``p95_admission_steps`` moving more than ``--tol-steps`` fleet steps,
  or ``tokens_total``/``steps_total`` moving more than a ``--tol-tokens``
  fraction, trips the gate (routing or drain-scheduling drift);
* maintenance event counts are diffed exactly — an extra or missing drain
  window means the planner changed behavior.

Wall-clock ``tokens_per_s`` is recorded but never diffed.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

from benchmarks import fleet_sweep

BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_fleet.json")

GATED_EVENTS = ("maintenance_requested", "drain_start", "reprogram_done",
                "canary_warning")


def _floor_of(key: str) -> float:
    return float(key.split("_floor")[1])


def _chips_of(key: str) -> int:
    return int(key.split("_floor")[0][1:])


def compare(results: dict, baseline: dict, tol_steps: float,
            tol_tokens: float) -> list:
    failures = []
    want_cells, got_cells = baseline["cells"], results["cells"]
    for key in sorted(set(want_cells) ^ set(got_cells)):
        side = "baseline" if key in want_cells else "sweep"
        failures.append(f"cell {key}: only present in the {side}; "
                        "re-record BENCH_fleet.json")
    for key in sorted(set(want_cells) & set(got_cells)):
        want, got = want_cells[key], got_cells[key]
        n, floor = _chips_of(key), _floor_of(key)
        # the invariant itself, independent of the baseline
        hard_floor = 1.0 - math.ceil(n * (1.0 - floor)) / n
        if got["min_accepting_frac"] < hard_floor - 1e-9:
            failures.append(
                f"{key}: capacity {got['min_accepting_frac']:.2f} dropped "
                f"below the planner floor {hard_floor:.2f} — the "
                "MaintenancePlanner invariant is broken")
        if abs(got["p95_admission_steps"]
               - want["p95_admission_steps"]) > tol_steps:
            failures.append(
                f"{key}: p95 admission {got['p95_admission_steps']:.0f} "
                f"steps vs baseline {want['p95_admission_steps']:.0f} "
                f"(tol {tol_steps:.0f})")
        for field in ("tokens_total", "steps_total"):
            bound = tol_tokens * max(want[field], 1)
            if abs(got[field] - want[field]) > bound:
                failures.append(
                    f"{key}: {field} {got[field]} vs baseline "
                    f"{want[field]} (tol {tol_tokens:.0%})")
        for ev in GATED_EVENTS:
            w, g = want["events"].get(ev, 0), got["events"].get(ev, 0)
            if w != g:
                failures.append(
                    f"{key}: {g} {ev!r} events vs baseline {w} — the "
                    "maintenance schedule changed")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol-steps", type=float, default=3.0,
                    help="p95 admission-latency delta allowed (fleet steps)")
    ap.add_argument("--tol-tokens", type=float, default=0.15,
                    help="relative tokens/steps-total delta allowed")
    args = ap.parse_args()

    with open(BASELINE) as f:
        baseline = json.load(f)
    if not baseline.get("quick", True):
        print("[fleet-gate] note: baseline was recorded with quick=False; "
              "the gate compares a quick run against it")
    results = fleet_sweep.run(quick=True)

    failures = compare(results, baseline, args.tol_steps, args.tol_tokens)
    if failures:
        print(f"\n[fleet-gate] FAIL — {len(failures)} deltas over "
              "tolerance vs benchmarks/BENCH_fleet.json:")
        for fail in failures:
            print("  " + fail)
        print("If the shift is intentional, re-record the (quick) "
              "baseline: rm benchmarks/BENCH_fleet.json && PYTHONPATH=src "
              "python -m benchmarks.run --only fleet_sweep")
        return 1
    print("\n[fleet-gate] OK — fleet orchestration within tolerance of "
          "BENCH_fleet.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
