"""Device-model sweep: accuracy vs drift time, INL vs redundancy, per preset.

Everything flows through ``repro.core.device`` presets — this is the
"many scenarios, one seam" benchmark:

* **ramp sweep**: mean programmed-NL-ADC INL for each preset with a build
  stage, across redundancy levels R=1/2/4 (Supp. S11 generalized to every
  device corner);
* **accuracy sweep**: one KWS LSTM hardware-aware-trained under ``paper``,
  then evaluated with its weight crossbars aged by each preset over drift
  time (Supp. S13 generalized: ``paper-infer`` at t=0 vs ``aged-1day`` vs
  multi-year shelf corners, plus the ``stressed`` chip).

Writes ``benchmarks/BENCH_device.json`` as the recorded baseline.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog_layer import AnalogConfig
from repro.core.device import Redundancy, get_device
from repro.core.nladc import build_ramp
from repro.nn import lstm as NN

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_device.json")

DRIFT_TIMES_S = (0.0, 1e3, 86_400.0, 5e5)
RAMP_PRESETS = ("paper-infer", "aged-1day", "stressed")
# aged-1day IS paper-infer.with_drift(86400), so its accuracy point is the
# t=9e+04s column of the paper-infer row — no separate sweep needed.
AGING_PRESETS = ("paper-infer", "stressed")


def _ramp_inl_sweep(quick: bool):
    n_chips = 8 if quick else 32
    out = {}
    ramp = build_ramp("gelu", 5)
    for preset in RAMP_PRESETS:
        base = get_device(preset)
        rows = {}
        for copies in (1, 2, 4):
            dev = base.replace(redundancy=Redundancy(n_copies=copies))
            inls = [dev.program(ramp, np.random.default_rng(500 + c)).inl()[0]
                    for c in range(n_chips)]
            rows[f"R{copies}"] = round(float(np.mean(inls)), 4)
        out[preset] = rows
        print(f"  {preset:12} " + "  ".join(
            f"{k}: {v:.3f}" for k, v in rows.items()))
    return out


def _accuracy_under(params, data, dev, seed: int = 0, tiled: bool = False,
                    bank_cols: int = 0, backend: str = ""):
    """Eval with weight crossbars aged by ``dev`` and the NL-ADC ramps
    programmed per ``dev`` (infer mode), read noise per minibatch.

    ``tiled=True`` ages via the deployment path (``age_params`` with no
    rng: per-tile TilePlan-keyed draws — what ``ServingEngine`` does);
    the default keeps the legacy sequential stream the recorded Supp. S13
    numbers are pinned on.  ``bank_cols`` deploys per-col-tile threshold
    banks (the (n_col_tiles, P) layout); ``backend`` selects the analog
    execution backend (pallas runs in interpret mode off-TPU).
    """
    (_, _), (xte, yte) = data
    spec = NN.LSTMSpec(
        n_in=40, n_hidden=32,
        analog=AnalogConfig(enabled=True, adc_bits=5, input_bits=5,
                            mode="infer", device=dev, bank_cols=bank_cols,
                            backend=backend))
    acts = NN.make_gate_acts(spec.analog, width=32 if bank_cols else 0)
    aged = dev.age_params(params) if tiled \
        else dev.age_params(params, np.random.default_rng(seed))

    @jax.jit
    def predict(p, xb, key):
        return jnp.argmax(NN.classifier_apply(p, xb, spec, acts, key=key), -1)

    pred = predict(aged, jnp.asarray(xte), jax.random.PRNGKey(100 + seed))
    return float(jnp.mean(pred == jnp.asarray(yte)))


def _accuracy_sweep(quick: bool):
    from benchmarks.s13_drift import train_kws
    from repro.data.pipeline import SyntheticKWS

    n_train = 512 if quick else 2048
    epochs = 3 if quick else 10
    data = SyntheticKWS(seed=0).splits(n_train, 256)
    # Alg. 1 training under the paper device — the shared recipe
    params = train_kws(data, epochs, get_device("paper"))
    out = {}
    for preset in AGING_PRESETS:
        base = get_device(preset)
        row = {}
        for t in DRIFT_TIMES_S:
            dev = base.with_drift(t) if t > 0 else base
            row[f"{t:.0e}s"] = round(_accuracy_under(params, data, dev), 4)
        out[preset] = row
        print(f"  {preset:12} " + "  ".join(
            f"t={k}:{v:.3f}" for k, v in row.items()))
    # drift hurts; the stressed corner's mitigation stack keeps it usable
    assert out["paper-infer"]["0e+00s"] >= 0.5
    # the DEPLOYMENT aging path (per-tile TilePlan-keyed draws, rng=None —
    # what ServingEngine actually runs) recorded separately so the CI gate
    # trips on regressions in the tile-keyed code too
    tiled = {}
    for preset in AGING_PRESETS:
        base = get_device(preset)
        row = {}
        for t in (0.0, 86_400.0):
            dev = base.with_drift(t) if t > 0 else base
            row[f"{t:.0e}s"] = round(
                _accuracy_under(params, data, dev, tiled=True), 4)
        tiled[preset] = row
        print(f"  {preset:12} (tiled) " + "  ".join(
            f"t={k}:{v:.3f}" for k, v in row.items()))
    # banked leg: per-col-tile threshold banks (n_col_tiles = 4 at H=32,
    # bank_cols=8), through BOTH analog backends (pallas interprets
    # off-TPU) — the gate trips on regressions anywhere in the banked
    # quantize/deploy path
    banked = {}
    for preset in AGING_PRESETS:
        base = get_device(preset)
        row = {}
        for be in ("ref", "pallas"):
            row[f"B4-{be}"] = round(
                _accuracy_under(params, data, base, tiled=True,
                                bank_cols=8, backend=be), 4)
        banked[preset] = row
        print(f"  {preset:12} (banked) " + "  ".join(
            f"{k}:{v:.3f}" for k, v in row.items()))
        # both backends quantize identically on the banked deployment
        assert abs(row["B4-ref"] - row["B4-pallas"]) < 0.02, row
    return out, tiled, banked


def run(quick=True):
    print("=== device sweep: programmed-ramp INL vs redundancy ===")
    ramp_inl = _ramp_inl_sweep(quick)
    print("=== device sweep: KWS accuracy vs drift time (aged crossbars) ===")
    accuracy, accuracy_tiled, accuracy_banked = _accuracy_sweep(quick)
    results = {
        "quick": quick,
        "ramp_inl_lsb": ramp_inl,
        "kws_accuracy": accuracy,
        "kws_accuracy_tiled": accuracy_tiled,
        "kws_accuracy_banked": accuracy_banked,
        "drift_times_s": list(DRIFT_TIMES_S),
    }
    if not quick or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"  baseline written to {OUT_PATH}")
    return results


if __name__ == "__main__":
    run(quick=False)
