"""Kernel block-size autotune sweep + bitwise kernel digests.

    PYTHONPATH=src python -m benchmarks.run --only kernel_tune

Three jobs in one module:

* run the :mod:`repro.kernels.tune` sweep over a representative kernel x
  shape grid (deterministic proxy scoring in interpret mode, measured wall
  time where ``REPRO_PALLAS_COMPILED=1`` actually lowers) and print the
  chosen blocks per shape;
* per tuned shape, record the jnp-ref wall time (the CPU-visible
  throughput proxy — NEVER gated), the interpret-mode correctness of the
  Pallas kernel vs its jnp oracle, and a crc32 digest of the kernel output
  bytes on seeded inputs (bitwise-gated by ``benchmarks.kernel_gate``);
* record the new-path parity section: threshold fast path vs the dense
  banked layout (bitwise), the fused MoE expert einsum vs the ref backend
  (ADC codes within LSB/2 + STE grads), and the Pallas cached-attention
  kernel vs ``attend_full`` (bitwise, output AND gradient).

The result (tune cache + digests + parity) is committed as
``benchmarks/BENCH_kernels.json``; re-record on real TPU to replace the
proxy-selected blocks with measured ones (see README "Kernel autotuning").
"""

from __future__ import annotations

import json
import os
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as BK
from repro.core.nladc import NLADC, BankedThresholds, bank_map_for, build_ramp
from repro.kernels import ops, ref, tune

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")

# kernel -> shapes swept and digested; bank_cols = 128 makes the threshold
# fast path eligible at lane blocks of 128 (bank_cols % bn == 0)
SHAPES_QUICK = {
    "fused_matmul_nladc": [(64, 128, 256), (128, 256, 512)],
    "nladc": [(128, 512)],
    "lstm_gates": [(32, 128)],
}
SHAPES_FULL = {
    "fused_matmul_nladc": [(64, 128, 256), (128, 256, 512),
                           (512, 1024, 1024)],
    "analog_tile": [(128, 256, 256)],
    "nladc": [(128, 512), (1024, 2048)],
    "lstm_gates": [(32, 128), (128, 512)],
}
BANK_COLS = 128


def _digest(*arrays) -> str:
    crc = 0
    for a in arrays:
        crc = zlib.crc32(np.ascontiguousarray(
            np.asarray(a, np.float32)).tobytes(), crc)
    return f"{crc:08x}"


def _ref_us(fn, *args, n: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return round((time.perf_counter() - t0) / n * 1e6, 1)


def _shape_cell(kernel, shape, blocks, ramp, sig, tnh, rng):
    """Digest + oracle error + jnp-ref wall time for one tuned shape."""
    if kernel in ("fused_matmul_nladc", "analog_tile"):
        m, k, n = shape
        x = jnp.asarray(rng.normal(0, 0.4, (m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, 0.2, (k, n)).astype(np.float32))
        if kernel == "fused_matmul_nladc":
            got = ops.fused_matmul_nladc(x, w, ramp, blocks=blocks)
            want = ref.fused_matmul_nladc(x, w, ramp)
            us = _ref_us(jax.jit(
                lambda a, b: ref.fused_matmul_nladc(a, b, ramp)), x, w)
        else:
            got = ops.analog_tile(x, w, ramp, blocks=blocks)
            want = ref.analog_tile(x, w, ramp)
            us = _ref_us(jax.jit(
                lambda a, b: ref.analog_tile(a, b, ramp)), x, w)
    elif kernel == "nladc":
        m, n = shape
        x = jnp.asarray(rng.normal(0, 2, (m, n)).astype(np.float32))
        got = ops.nladc(x, ramp, block=blocks)
        want = ref.nladc(x, ramp)
        us = _ref_us(jax.jit(lambda a: ref.nladc(a, ramp)), x)
    else:  # lstm_gates
        b, h = shape
        g = jnp.asarray(rng.normal(0, 1.5, (b, 4 * h)).astype(np.float32))
        c = jnp.asarray(rng.normal(0, 0.5, (b, h)).astype(np.float32))
        got = ops.lstm_gates(g, c, sig, tnh, block=blocks)
        want = ref.lstm_gates(g, c, sig, tnh)
        got = jnp.concatenate(got, axis=-1)
        want = jnp.concatenate(want, axis=-1)
        us = _ref_us(jax.jit(
            lambda a, b2: jnp.concatenate(
                ref.lstm_gates(a, b2, sig, tnh), axis=-1)), g, c)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    return {"blocks": list(blocks), "digest": _digest(got),
            "max_err_vs_ref": err, "ref_us": us}


def _parity_section(rng):
    """The new-path parity cells the gate enforces bitwise / in LSB."""
    ramp = build_ramp("swish", 5)
    adc = NLADC(ramp)
    lsb = float(ramp.lsb)
    out = {}

    # --- threshold fast path vs dense banked layout (bitwise) ---
    n, p_len = 256, int(np.asarray(ramp.thresholds).shape[0])
    bm = bank_map_for(n, BANK_COLS)
    thr = jnp.asarray(np.sort(rng.normal(0, 1, (bm.n_banks, p_len)),
                              axis=1).astype(np.float32))
    bt = BankedThresholds(thr, bm)
    x = jnp.asarray(rng.normal(0, 1.5, (32, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, (64, n)).astype(np.float32))
    xm = jnp.asarray(rng.normal(0, 0.5, (16, 64)).astype(np.float32))
    blocks = (256, BANK_COLS, 512)
    from repro.kernels.common import BlockRowThresholds
    assert isinstance(ops._resolve_thr(bt, n, BANK_COLS),
                      BlockRowThresholds), \
        "fast-path carrier not selected for the aligned bank layout"
    fast_n = ops.nladc(x, ramp, thresholds=bt, block=(256, BANK_COLS))
    fast_m = ops.fused_matmul_nladc(xm, w, ramp, thresholds=bt,
                                    blocks=blocks)
    os.environ["REPRO_KERNEL_FASTPATH"] = "0"
    try:
        dense_n = ops.nladc(x, ramp, thresholds=bt, block=(256, BANK_COLS))
        dense_m = ops.fused_matmul_nladc(xm, w, ramp, thresholds=bt,
                                         blocks=blocks)
    finally:
        del os.environ["REPRO_KERNEL_FASTPATH"]
    out["fastpath"] = {
        "bitwise_equal": bool(jnp.array_equal(fast_n, dense_n))
        and bool(jnp.array_equal(fast_m, dense_m)),
        "digest": _digest(fast_n, fast_m),
    }

    # --- fused MoE expert einsum vs ref backend (codes + STE grads) ---
    e_dim, c_dim, d_dim, f_dim = 4, 8, 64, n
    xe = jnp.asarray(rng.normal(0, 0.5,
                                (e_dim, c_dim, d_dim)).astype(np.float32))
    we = jnp.asarray(rng.normal(0, 0.3,
                                (e_dim, d_dim, f_dim)).astype(np.float32))
    pb, rb = BK.get_backend("pallas"), BK.get_backend("ref")
    y_p = pb.moe_matmul_nladc(xe, we, adc, bt)
    y_r = rb.moe_matmul_nladc(xe, we, adc, bt)
    g_p = jax.grad(lambda a: jnp.sum(pb.moe_matmul_nladc(a, we, adc,
                                                         bt)))(xe)
    g_r = jax.grad(lambda a: jnp.sum(rb.moe_matmul_nladc(a, we, adc,
                                                         bt)))(xe)
    out["moe_einsum"] = {
        "max_err_lsb": float(jnp.max(jnp.abs(y_p - y_r))) / lsb,
        "grad_max_err": float(jnp.max(jnp.abs(g_p - g_r))),
        "digest": _digest(y_p),
    }

    # --- Pallas cached attention vs attend_full (bitwise + grads) ---
    b, h, hkv, d, s = 3, 8, 2, 16, 24
    q = jnp.asarray(rng.normal(0, 1, (b, 1, h, d)).astype(np.float32))
    kc = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32))
    vc = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)).astype(np.float32))
    mask = (jnp.arange(s) < 17)[None, None, :]
    o_p = pb.prefill_attention(q, kc, vc, mask)
    o_r = rb.prefill_attention(q, kc, vc, mask)
    gq_p = jax.grad(lambda a: jnp.sum(pb.prefill_attention(a, kc, vc,
                                                           mask)))(q)
    gq_r = jax.grad(lambda a: jnp.sum(rb.prefill_attention(a, kc, vc,
                                                           mask)))(q)
    out["attention"] = {
        "bitwise_equal": bool(jnp.array_equal(o_p, o_r)),
        "grad_max_err": float(jnp.max(jnp.abs(gq_p - gq_r))),
        "digest": _digest(o_p),
    }
    return out


def run(quick=True):
    shapes = SHAPES_QUICK if quick else SHAPES_FULL
    ramp = build_ramp("sigmoid", 5)
    sig, tnh = build_ramp("sigmoid", 5), build_ramp("tanh", 5)
    print("=== kernel autotune sweep "
          f"({tune.platform()}/{tune.backend_mode()}) ===")
    cache = tune.autotune(shapes)
    cells = {}
    for kernel, shape_list in sorted(shapes.items()):
        for shape in shape_list:
            rng = np.random.default_rng(0)
            blocks = cache.lookup(kernel, shape)
            cell = _shape_cell(kernel, shape, blocks, ramp, sig, tnh, rng)
            key = f"{kernel}|" + "x".join(map(str, shape))
            cells[key] = cell
            print(f"  {key:42} blocks={tuple(blocks)}  "
                  f"err={cell['max_err_vs_ref']:.2e}  "
                  f"ref {cell['ref_us']:8.1f} us  "
                  f"digest {cell['digest']}")

    parity = _parity_section(np.random.default_rng(7))
    print(f"  fastpath bitwise: {parity['fastpath']['bitwise_equal']}   "
          f"moe err {parity['moe_einsum']['max_err_lsb']:.3f} LSB "
          f"(grad {parity['moe_einsum']['grad_max_err']:.1e})   "
          f"attention bitwise: {parity['attention']['bitwise_equal']}")

    results = {"quick": quick, "platform": tune.platform(),
               "backend_mode": tune.backend_mode(),
               "tune": cache.to_dict(), "shapes": cells, "parity": parity}
    if not quick or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"  baseline written to {OUT_PATH}")
    return results


if __name__ == "__main__":
    run(quick=False)
