"""Fig. 4d: KWS accuracy vs NL-ADC resolution (float / 5b / 4b / 3b).

GSCD is gated offline -> deterministic synthetic 12-class MFCC-like dataset
(DESIGN §Dataset gates); the claim validated is the paper's *relative*
structure: float >= 5b >= 4b >= 3b, small deltas, noise-aware training
recovering most of the write-noise drop.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog_layer import AnalogConfig
from repro.data.pipeline import SyntheticKWS
from repro.nn import lstm as NN
from repro.train import optim


def _make(bits, mode, enabled=True):
    return NN.LSTMSpec(
        n_in=40, n_hidden=32,
        analog=AnalogConfig(enabled=enabled, adc_bits=bits, input_bits=bits,
                            mode=mode))


def train_eval(spec, data, *, epochs=6, lr=3e-3, seed=0, eval_spec=None):
    (xtr, ytr), (xte, yte) = data
    acts = NN.make_gate_acts(spec.analog)
    params = NN.classifier_init(jax.random.PRNGKey(seed), spec, 12)
    opt = optim.Adam(lr=lr)
    state = opt.init(params)

    def loss_fn(p, xb, yb, key):
        logits = NN.classifier_apply(p, xb, spec, acts, key=key)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(p, s, xb, yb, key):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb, key)
        p, s = opt.update(g, s, p)
        return p, s, l

    bs = 64
    n = len(xtr)
    key = jax.random.PRNGKey(seed + 1)
    for ep in range(epochs):
        perm = np.random.default_rng(ep).permutation(n)
        for i in range(0, n - bs + 1, bs):
            idx = perm[i:i + bs]
            key, k = jax.random.split(key)
            params, state, _ = step(params, state,
                                    jnp.asarray(xtr[idx]),
                                    jnp.asarray(ytr[idx]), k)

    espec = eval_spec or spec
    eacts = NN.make_gate_acts(espec.analog)

    @jax.jit
    def predict(p, xb, key):
        return jnp.argmax(
            NN.classifier_apply(p, xb, espec, eacts, key=key), -1)

    accs = []
    n_chips = 3
    for chip in range(n_chips):   # paper: 10 chip simulations
        kk = jax.random.PRNGKey(100 + chip)
        pred = predict(params, jnp.asarray(xte), kk)
        accs.append(float(jnp.mean(pred == jnp.asarray(yte))))
    return float(np.mean(accs)), float(np.std(accs))


def run(quick=True):
    n_train = 768 if quick else 3072
    epochs = 4 if quick else 12
    data = SyntheticKWS(seed=0).splits(n_train, 384)
    print("=== Fig. 4d: KWS accuracy vs NL-ADC bits (synthetic GSCD) ===")
    rows = {}
    t0 = time.time()
    # float baseline
    acc, sd = train_eval(_make(5, "exact", enabled=False), data,
                         epochs=epochs)
    rows["float"] = acc
    print(f"float baseline: {acc:.3f}")
    for bits in (5, 4, 3):
        # noise-aware training (Alg. 1), noisy inference (write+read noise)
        spec_t = _make(bits, "train")
        spec_e = _make(bits, "infer")
        acc, sd = train_eval(spec_t, data, epochs=epochs, eval_spec=spec_e)
        rows[f"{bits}b"] = acc
        print(f"{bits}-bit NL-ADC + noise-aware train, noisy infer: "
              f"{acc:.3f} +/- {sd:.3f}")
    print(f"(paper: 91.6 fp / 88.5 5b / 86.6 4b / 85.2 3b on real GSCD; "
          f"{time.time() - t0:.0f}s)")
    ok = rows["float"] >= rows["5b"] - 0.02 and rows["5b"] >= rows["3b"] - 0.02
    print("ordering float >= 5b >= 3b:", "OK" if ok else "VIOLATED")
    return rows


if __name__ == "__main__":
    run(quick=False)
