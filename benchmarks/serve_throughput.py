"""Offline serving throughput: legacy scan prefill vs the bucketed path.

The throughput claim (``repro.serve.engine``): an MLPerf-offline-style
burst of mixed-length prompts decodes at >= 2x the tokens/s of the
legacy one-slot scan-prefill path once prefill goes through power-of-two
AOT bucket executables with prompt packing, because

* the scan path re-traces ``prefill_cache`` for every distinct prompt
  length *inside the measured burst* (its ``warmup()`` can only
  pre-compile the decode step — prefill shapes arrive with the traffic);
* the bucketed path pays all prefill compiles in ``warmup()`` and packs
  up to ``max_batch`` prompts into one padded prefill call.

Three cells, identical config / burst / backend:

* ``scan``               the legacy path (``prefill="scan"``);
* ``bucketed_pack``      AOT buckets + prompt packing;
* ``bucketed_pack_detok``  the above plus the background detokenize
                           thread overlapping host transfer with the
                           next device step;
* ``bucketed_pack_obs``    the bucketed cell with FULL observability on
                           (``repro.obs``: span/event tracing with
                           wall-clock fields, metrics, energy counters)
                           — the obs-overhead leg.  Its tokens/s over
                           the plain bucketed cell is recorded as
                           ``obs_overhead`` and gated >= 0.95 by
                           ``benchmarks.serve_gate`` (observability must
                           cost < 5% throughput).

Every cell's per-request token streams must be **bitwise identical** to
the scan cell's — asserted here, so a throughput win can never come from
numerics drift (and observability can never perturb a token).  Streams
and token totals land in the baseline for ``benchmarks.serve_gate`` to
diff exactly; wall-clock tokens/s is recorded but the gate only checks
the scan-normalized speedup ratio and the same-run obs-overhead ratio
(machine-speed independent).

Writes ``benchmarks/BENCH_serve.json``.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro import configs
from repro.configs.base import AnalogSpec
from repro.nn.model import build
from repro.obs import Obs
from repro.serve.engine import Request, ServingEngine

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")

# mixed-length burst: duplicates AND distinct lengths, plus the
# degenerate single-token prompt (no prefill at all)
LENGTHS_QUICK = (5, 13, 1, 22, 9, 17, 3, 30)
LENGTHS_FULL = LENGTHS_QUICK + (11, 26, 7, 19, 2, 28, 15, 24)

MAX_BATCH = 4
MAX_LEN = 48
MAX_NEW = 4

CELLS = (
    ("scan", dict(prefill="scan")),
    ("bucketed_pack", dict(prefill="bucketed", pack_prefill=True)),
    ("bucketed_pack_detok", dict(prefill="bucketed", pack_prefill=True,
                                 detok_thread=True)),
    # full observability on: worst-case obs cost (tracing + wall clock)
    ("bucketed_pack_obs", dict(prefill="bucketed", pack_prefill=True,
                               full_obs=True)),
)


def _burst(cfg, lengths):
    rng = np.random.default_rng(7)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, n in enumerate(lengths)]


def _cell(model, params, cfg, lengths, full_obs=False, **kw) -> dict:
    obs = Obs(trace=True, wall_clock=True) if full_obs else None
    eng = ServingEngine(model, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                        obs=obs, **kw)
    reqs = _burst(cfg, lengths)
    warm = eng.warmup()            # compile time paid here, outside the clock
    stats = eng.run_offline(reqs)
    cell = {
        "tokens_total": stats["tokens"],
        "seconds": round(stats["seconds"], 3),
        "tokens_per_s": round(stats["tokens_per_s"], 1),
        "buckets": list(warm["prefill_buckets"]),
        "streams": {str(r.uid): [int(t) for t in r.generated] for r in reqs},
    }
    if full_obs:
        cell["trace_entries"] = len(eng.obs.tracer.entries)
    return cell


def run(quick=True):
    lengths = LENGTHS_QUICK if quick else LENGTHS_FULL
    cfg = configs.get_smoke("qwen2.5-3b").replace(
        dtype="float32", analog=AnalogSpec(enabled=False))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    cells = {}
    for name, kw in CELLS:
        print(f"=== offline burst ({len(lengths)} reqs, max_new {MAX_NEW}): "
              f"{name} ===")
        cell = _cell(model, params, cfg, lengths, **kw)
        cells[name] = cell
        print(f"  {cell['tokens_total']} tok in {cell['seconds']:.2f}s "
              f"({cell['tokens_per_s']} tok/s)  buckets {cell['buckets']}")

    # bitwise parity: a throughput win must not move a single token
    for name in cells:
        assert cells[name]["streams"] == cells["scan"]["streams"], \
            f"cell {name!r} token streams diverged from the scan path"
        assert cells[name]["tokens_total"] == cells["scan"]["tokens_total"]

    base = cells["scan"]["tokens_per_s"]
    speedup = {name: round(cells[name]["tokens_per_s"] / max(base, 1e-9), 2)
               for name, _ in CELLS if name != "scan"}
    print(f"  speedup over scan prefill: {speedup}")
    if speedup["bucketed_pack"] < 2.0:
        print("  WARNING: bucketed_pack below the 2x offline target")
    # obs-overhead leg: full tracing vs the identical cell without it,
    # measured as best-of-N cache-warm re-runs of BOTH variants,
    # alternating (the single recorded cells are too short — tens of ms
    # — and cell order biases them: the first bucketed cell pays
    # in-process jit tracing that every later cell reuses).
    warm_best, obs_best = 0.0, 0.0
    for _ in range(3):
        warm_best = max(warm_best, _cell(
            model, params, cfg, lengths,
            prefill="bucketed", pack_prefill=True)["tokens_per_s"])
        obs_best = max(obs_best, _cell(
            model, params, cfg, lengths, full_obs=True,
            prefill="bucketed", pack_prefill=True)["tokens_per_s"])
    obs_overhead = round(obs_best / max(warm_best, 1e-9), 3)
    print(f"  obs overhead: {obs_overhead:.3f}x of warm bucketed_pack "
          f"(best-of-3: {obs_best} vs {warm_best} tok/s, "
          f"{cells['bucketed_pack_obs']['trace_entries']} trace entries)")

    results = {"quick": quick, "lengths": list(lengths),
               "max_batch": MAX_BATCH, "max_len": MAX_LEN,
               "max_new": MAX_NEW, "cells": cells, "speedup": speedup,
               "obs_overhead": obs_overhead,
               "obs_overhead_base_tokens_per_s": warm_best}
    if not quick or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"  baseline written to {OUT_PATH}")
    return results


if __name__ == "__main__":
    run(quick=True)
