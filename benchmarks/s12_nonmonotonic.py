"""Supp. S12 / Fig. S13: non-monotonic (GELU/Swish) extremum-split NL-ADC,
including the refined more-negative-points variant (Fig. S13f/g)."""

import numpy as np

from repro.core import functions as F
from repro.core.nladc import (build_nonmonotonic_ramp, nladc_reference,
                              transfer_mse)


def run(quick=True):
    print("=== Supp. S12: non-monotonic NL-ADC (5-bit) ===")
    out = {}
    for name in ("gelu", "swish"):
        spec = F.get(name)
        base = build_nonmonotonic_ramp(name, 5)
        fine = build_nonmonotonic_ramp(name, 5, extra_negative_points=4)
        xs = np.linspace(spec.x_lo + 1e-2, spec.x_hi - 1e-2, 3000)
        neg = xs[xs < float(spec.x_extremum)]
        err_b = np.abs(nladc_reference(neg, base) - spec.fwd(neg)).mean()
        err_f = np.abs(nladc_reference(neg, fine) - spec.fwd(neg)).mean()
        print(f"{name:6} split@code {base.split_index:2d}  "
              f"MSE {transfer_mse(base):.5f}  "
              f"neg-branch MAE {err_b:.4f} -> {err_f:.4f} w/ extra points")
        out[name] = dict(mse=transfer_mse(base),
                         neg_mae_base=float(err_b),
                         neg_mae_refined=float(err_f))
    print("(paper: refined INL -1.1 -> -0.24 LSB GELU, -0.91 -> -0.13 Swish)")
    return out


if __name__ == "__main__":
    run()
