"""Supp. S13 / Fig. S15: long-term RRAM drift effect on KWS accuracy.

Reference-curve drift model (Eq. S8); validates the paper's qualitative
findings: (a) drift on the NL-ADC alone is negligible; (b) drift on weights
degrades accuracy over time; (c) larger training noise restores robustness.

Rewritten over ``repro.core.device``: training noise is a ``TrainNoise``
stage on a custom DeviceModel, and each evaluation time point is the
``paper`` preset aged with ``DeviceModel.with_drift(t)`` whose
``age_params`` drifts the weight matrices (seeded parity with the old
hand-wired ``DriftModel.drift_weights`` tree.map is pinned by
``tests/test_device.py``).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog_layer import AnalogConfig
from repro.core.device import ReadNoise, TrainNoise, get_device
from repro.nn import lstm as NN

DRIFT_TIMES_S = (60.0, 1e3, 1e5, 5e5)


def _train_device(sigma_us: float):
    """The paper's step-time model with Alg. 1 noise set to ``sigma_us``."""
    return get_device("paper").replace(
        name=f"paper-train{sigma_us:g}uS",
        train=TrainNoise(sigma_us=sigma_us), read=ReadNoise())


def train_kws(data, epochs: int, device, n_classes: int = 12):
    """The paper's Alg. 1 KWS training recipe under ``device``.

    Shared by this benchmark and ``benchmarks.device_sweep`` so the recipe
    (Adam 3e-3, batch-64 permutation epochs, per-step noise keys) cannot
    diverge between them.  Returns the trained params.
    """
    from repro.train import optim

    spec = NN.LSTMSpec(
        n_in=40, n_hidden=32,
        analog=AnalogConfig(enabled=True, adc_bits=5, input_bits=5,
                            mode="train", device=device))
    acts = NN.make_gate_acts(spec.analog)
    params = NN.classifier_init(jax.random.PRNGKey(0), spec, n_classes)
    opt = optim.Adam(lr=3e-3)
    state = opt.init(params)

    def loss_fn(p, xb, yb, key):
        logits = NN.classifier_apply(p, xb, spec, acts, key=key)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(p, s, xb, yb, key):
        _, g = jax.value_and_grad(loss_fn)(p, xb, yb, key)
        return opt.update(g, s, p)

    (xtr, ytr), _ = data
    key = jax.random.PRNGKey(1)
    for ep in range(epochs):
        perm = np.random.default_rng(ep).permutation(len(xtr))
        for i in range(0, len(xtr) - 63, 64):
            idx = perm[i:i + 64]
            key, k = jax.random.split(key)
            params, state = step(params, state, jnp.asarray(xtr[idx]),
                                 jnp.asarray(ytr[idx]), k)
    return params


def _eval_with_drift(params, spec, data, aged_dev, rng):
    (_, _), (xte, yte) = data
    acts = NN.make_gate_acts(spec.analog)
    drifted = aged_dev.age_params(params, rng)

    @jax.jit
    def predict(p, xb):
        return jnp.argmax(NN.classifier_apply(p, xb, spec, acts), -1)

    pred = predict(drifted, jnp.asarray(xte))
    return float(jnp.mean(pred == jnp.asarray(yte)))


def run(quick=True):
    n_train = 512 if quick else 2048
    epochs = 3 if quick else 10
    from repro.data.pipeline import SyntheticKWS

    data = SyntheticKWS(seed=0).splits(n_train, 256)
    print("=== Supp. S13: accuracy vs drift time (synthetic KWS) ===")

    # train once with standard (5 uS) and larger (8 uS) training noise
    out = {}
    for label, sigma in (("train 5uS", 5.0), ("train 8uS", 8.0)):
        params = train_kws(data, epochs, _train_device(sigma))
        spec_e = NN.LSTMSpec(n_in=40, n_hidden=32,
                             analog=AnalogConfig(enabled=True, adc_bits=5,
                                                 input_bits=5, mode="exact"))
        accs = []
        for t in DRIFT_TIMES_S:
            aged = get_device("paper").with_drift(t)
            rng = np.random.default_rng(int(t))
            accs.append(_eval_with_drift(params, spec_e, data, aged, rng))
        print(f"  {label}: " + "  ".join(
            f"t={t:.0e}s:{a:.3f}" for t, a in zip(DRIFT_TIMES_S, accs)))
        out[label] = dict(zip([f"{t:.0e}" for t in DRIFT_TIMES_S], accs))
    d5 = out["train 5uS"]
    d8 = out["train 8uS"]
    print(f"  drop@5e5s: 5uS {d5['6e+01'] - d5['5e+05']:+.3f}, "
          f"8uS {d8['6e+01'] - d8['5e+05']:+.3f} "
          "(paper: ~6% -> <2% with larger training noise)")
    return out


if __name__ == "__main__":
    run(quick=False)
