"""Supp. S13 / Fig. S15: long-term RRAM drift effect on KWS accuracy.

Reference-curve drift model (Eq. S8); validates the paper's qualitative
findings: (a) drift on the NL-ADC alone is negligible; (b) drift on weights
degrades accuracy over time; (c) larger training noise restores robustness.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog_layer import AnalogConfig
from repro.core.crossbar import DriftModel
from repro.data.pipeline import SyntheticKWS
from repro.nn import lstm as NN
from benchmarks.fig4d_kws import train_eval, _make


def _eval_with_drift(params, spec, data, t_s, dm, rng):
    (_, _), (xte, yte) = data
    acts = NN.make_gate_acts(spec.analog)
    drifted = jax.tree.map(
        lambda w: jnp.asarray(
            dm.drift_weights(np.asarray(w, np.float64), t_s, rng)
            .astype(np.float32)) if w.ndim >= 2 else w, params)

    @jax.jit
    def predict(p, xb):
        return jnp.argmax(NN.classifier_apply(p, xb, spec, acts), -1)

    pred = predict(drifted, jnp.asarray(xte))
    return float(jnp.mean(pred == jnp.asarray(yte)))


def run(quick=True):
    n_train = 512 if quick else 2048
    epochs = 3 if quick else 10
    data = SyntheticKWS(seed=0).splits(n_train, 256)
    dm = DriftModel()
    print("=== Supp. S13: accuracy vs drift time (synthetic KWS) ===")

    # train once with standard (5 uS) and larger (8 uS) training noise
    import repro.core.crossbar as CB
    from repro.nn.lstm import LSTMSpec

    out = {}
    for label, sigma in (("train 5uS", 5.0), ("train 8uS", 8.0)):
        spec_t = NN.LSTMSpec(
            n_in=40, n_hidden=32,
            analog=AnalogConfig(enabled=True, adc_bits=5, input_bits=5,
                                mode="train",
                                train_sigma_w=sigma / CB.GAMMA_US,
                                ramp_train_sigma_us=sigma))
        acts = NN.make_gate_acts(spec_t.analog)
        params = NN.classifier_init(jax.random.PRNGKey(0), spec_t, 12)
        from repro.train import optim

        opt = optim.Adam(lr=3e-3)
        state = opt.init(params)

        def loss_fn(p, xb, yb, key):
            logits = NN.classifier_apply(p, xb, spec_t, acts, key=key)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

        @jax.jit
        def step(p, s, xb, yb, key):
            l, g = jax.value_and_grad(loss_fn)(p, xb, yb, key)
            return *opt.update(g, s, p), l

        (xtr, ytr), _ = data
        key = jax.random.PRNGKey(1)
        for ep in range(epochs):
            perm = np.random.default_rng(ep).permutation(len(xtr))
            for i in range(0, len(xtr) - 63, 64):
                idx = perm[i:i + 64]
                key, k = jax.random.split(key)
                params, state, _ = step(params, state, jnp.asarray(xtr[idx]),
                                        jnp.asarray(ytr[idx]), k)

        spec_e = NN.LSTMSpec(n_in=40, n_hidden=32,
                             analog=AnalogConfig(enabled=True, adc_bits=5,
                                                 input_bits=5, mode="exact"))
        accs = []
        times = [60.0, 1e3, 1e5, 5e5]
        for t in times:
            rng = np.random.default_rng(int(t))
            accs.append(_eval_with_drift(params, spec_e, data, t, dm, rng))
        print(f"  {label}: " + "  ".join(
            f"t={t:.0e}s:{a:.3f}" for t, a in zip(times, accs)))
        out[label] = dict(zip([f"{t:.0e}" for t in times], accs))
    d5 = out["train 5uS"]
    d8 = out["train 8uS"]
    print(f"  drop@5e5s: 5uS {d5['6e+01'] - d5['5e+05']:+.3f}, "
          f"8uS {d8['6e+01'] - d8['5e+05']:+.3f} "
          "(paper: ~6% -> <2% with larger training noise)")
    return out


if __name__ == "__main__":
    run(quick=False)
