"""Fig. 3a + Fig. S7: programmed transfer functions, INL with/without
one-point calibration (64 columns per block, write sigma = 2.67 uS)."""

import numpy as np

from repro.core.calibration import program_ramp
from repro.core.nladc import build_ramp

FUNCS = ("sigmoid", "tanh", "softplus", "softsign", "elu", "selu")


def run(quick=True):
    n_cols = 16 if quick else 64
    print("=== Fig. 3a: mean INL (LSB) over programmed columns ===")
    print(f"{'fn':10} {'raw':>8} {'calibrated':>11} {'improvement':>12}")
    out = {}
    for name in FUNCS:
        ramp = build_ramp(name, 5)
        raw, cal = [], []
        for c in range(n_cols):
            rng = np.random.default_rng(c)
            raw.append(program_ramp(ramp, rng, calibrate=False).inl()[0])
            rng = np.random.default_rng(c)
            cal.append(program_ramp(ramp, rng, calibrate=True).inl()[0])
        r, c_ = float(np.mean(raw)), float(np.mean(cal))
        print(f"{name:10} {r:8.3f} {c_:11.3f} {r - c_:11.3f}")
        out[name] = dict(raw=r, calibrated=c_)
    avg_r = np.mean([v["raw"] for v in out.values()])
    avg_c = np.mean([v["calibrated"] for v in out.values()])
    print(f"average: {avg_r:.3f} -> {avg_c:.3f} LSB "
          "(paper: 0.948 -> 0.886)")
    return out


if __name__ == "__main__":
    run(quick=False)
