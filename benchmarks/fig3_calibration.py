"""Fig. 3a + Fig. S7: programmed transfer functions, INL with/without
one-point calibration (64 columns per block, write sigma = 2.67 uS).

A thin sweep over ``repro.core.device`` models: each column is one
:meth:`DeviceModel.program` call under the ``paper-infer`` preset, with the
"raw" arm simply switching the ``Calibration`` stage off.  Seeded
numerical parity with the pre-device-API hand-wired
``program_ramp(..., calibrate=...)`` sequence is pinned by
``tests/test_device.py``.
"""

import dataclasses

import numpy as np

from repro.core.device import Calibration, get_device
from repro.core.nladc import build_ramp

FUNCS = ("sigmoid", "tanh", "softplus", "softsign", "elu", "selu")


def run(quick=True):
    n_cols = 16 if quick else 64
    calibrated_dev = get_device("paper-infer")
    raw_dev = dataclasses.replace(calibrated_dev, name="paper-infer-raw",
                                  calibration=Calibration(one_point=False))
    print("=== Fig. 3a: mean INL (LSB) over programmed columns ===")
    print(f"{'fn':10} {'raw':>8} {'calibrated':>11} {'improvement':>12}")
    out = {}
    for name in FUNCS:
        ramp = build_ramp(name, 5)
        raw, cal = [], []
        for c in range(n_cols):
            raw.append(raw_dev.program(
                ramp, np.random.default_rng(c)).inl()[0])
            cal.append(calibrated_dev.program(
                ramp, np.random.default_rng(c)).inl()[0])
        r, c_ = float(np.mean(raw)), float(np.mean(cal))
        print(f"{name:10} {r:8.3f} {c_:11.3f} {r - c_:11.3f}")
        out[name] = dict(raw=r, calibrated=c_)
    avg_r = np.mean([v["raw"] for v in out.values()])
    avg_c = np.mean([v["calibrated"] for v in out.values()])
    print(f"average: {avg_r:.3f} -> {avg_c:.3f} LSB "
          "(paper: 0.948 -> 0.886)")
    return out


if __name__ == "__main__":
    run(quick=False)
