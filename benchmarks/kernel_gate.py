"""Kernel CI gate: bitwise output digests + new-path parity, never wall clock.

    PYTHONPATH=src python -m benchmarks.kernel_gate

Re-runs the quick ``benchmarks.kernel_tune`` sweep and fails — exit code
1 — when the kernel layer drifts from the committed
``benchmarks/BENCH_kernels.json``:

* the tuned blocks per kernel x shape must match the baseline (the
  deterministic proxy sweep moved — intentional retunes re-record);
* every shape cell's output digest must match EXACTLY (crc32 of the
  kernel output bytes on seeded inputs — a single flipped ADC code fails
  the gate) and the interpret-mode error vs the jnp oracle must stay at
  the recorded scale;
* the parity section must hold: threshold fast path bitwise-equal to the
  dense banked layout, fused MoE einsum within LSB/2 of the ref backend
  (codes) with matching STE grads, Pallas cached attention bitwise-equal
  to ``attend_full`` — all with digests matching the baseline.

``ref_us`` timings are recorded context only and are never compared.
"""

from __future__ import annotations

import json
import os
import sys

from benchmarks import kernel_tune

BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")
MOE_LSB_TOL = 0.5      # codes equal: decoded outputs within half an LSB
GRAD_TOL = 1e-5


def compare(results: dict, baseline: dict) -> list:
    failures = []
    for meta in ("platform", "backend_mode"):
        if results[meta] != baseline[meta]:
            failures.append(
                f"{meta}: sweep ran on {results[meta]!r} but the baseline "
                f"was recorded on {baseline[meta]!r}; re-record "
                "BENCH_kernels.json for this platform")

    want_cells, got_cells = baseline["shapes"], results["shapes"]
    for key in sorted(set(want_cells) ^ set(got_cells)):
        side = "baseline" if key in want_cells else "sweep"
        failures.append(f"shape {key}: only present in the {side}; "
                        "re-record BENCH_kernels.json")
    for key in sorted(set(want_cells) & set(got_cells)):
        want, got = want_cells[key], got_cells[key]
        if got["blocks"] != want["blocks"]:
            failures.append(
                f"{key}: tuned blocks {got['blocks']} vs baseline "
                f"{want['blocks']} — the autotune selection moved")
        if got["digest"] != want["digest"]:
            failures.append(
                f"{key}: output digest {got['digest']} vs baseline "
                f"{want['digest']} — the kernel numerics moved (bitwise)")
        if got["max_err_vs_ref"] > max(2.0 * want["max_err_vs_ref"], 1e-6):
            failures.append(
                f"{key}: interpret-mode error vs oracle "
                f"{got['max_err_vs_ref']:.2e} vs recorded "
                f"{want['max_err_vs_ref']:.2e}")

    wp, gp = baseline["parity"], results["parity"]
    if not gp["fastpath"]["bitwise_equal"]:
        failures.append("fastpath: (P,) bank-row fast path is NOT bitwise "
                        "equal to the dense banked layout")
    if gp["fastpath"]["digest"] != wp["fastpath"]["digest"]:
        failures.append(
            f"fastpath: digest {gp['fastpath']['digest']} vs baseline "
            f"{wp['fastpath']['digest']}")
    moe = gp["moe_einsum"]
    if moe["max_err_lsb"] >= MOE_LSB_TOL:
        failures.append(
            f"moe_einsum: pallas vs ref {moe['max_err_lsb']:.3f} LSB "
            f"(>= {MOE_LSB_TOL}) — ADC codes diverge")
    if moe["grad_max_err"] > GRAD_TOL:
        failures.append(
            f"moe_einsum: STE grad diff {moe['grad_max_err']:.2e} "
            f"(> {GRAD_TOL:.0e})")
    if moe["digest"] != wp["moe_einsum"]["digest"]:
        failures.append(
            f"moe_einsum: digest {moe['digest']} vs baseline "
            f"{wp['moe_einsum']['digest']}")
    att = gp["attention"]
    if not att["bitwise_equal"]:
        failures.append("attention: Pallas cached attention is NOT "
                        "bitwise equal to attend_full")
    if att["grad_max_err"] > GRAD_TOL:
        failures.append(
            f"attention: grad diff {att['grad_max_err']:.2e} "
            f"(> {GRAD_TOL:.0e})")
    if att["digest"] != wp["attention"]["digest"]:
        failures.append(
            f"attention: digest {att['digest']} vs baseline "
            f"{wp['attention']['digest']}")
    return failures


def main() -> int:
    with open(BASELINE) as f:
        baseline = json.load(f)
    results = kernel_tune.run(quick=True)
    failures = compare(results, baseline)
    if failures:
        print(f"\n[kernel-gate] FAIL — {len(failures)} deltas vs "
              "benchmarks/BENCH_kernels.json:")
        for fail in failures:
            print("  " + fail)
        print("If the shift is intentional, re-record: rm "
              "benchmarks/BENCH_kernels.json && PYTHONPATH=src python -m "
              "benchmarks.run --only kernel_tune")
        return 1
    print("\n[kernel-gate] OK — tuned blocks + kernel digests bitwise vs "
          "BENCH_kernels.json; fast path, MoE einsum, and attention "
          "parity hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
