"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Each module exposes ``run(quick) -> dict``; failures are collected and the
exit code reflects overall success.
"""

import argparse
import sys
import time
import traceback

MODULES = [
    ("tab_s2_ramps", "Tab. S1/S2 + Fig. 2d/2e ramp tables"),
    ("fig3_calibration", "Fig. 3a / Fig. S7 calibration INL"),
    ("fig3b_vread", "Fig. 3b V_read robustness"),
    ("s11_redundancy", "Supp. S11 redundancy"),
    ("s12_nonmonotonic", "Supp. S12 GELU/Swish split"),
    ("tab_s5_macro", "Tab. S3-S5 KWS macro costs"),
    ("tab_s9_nlp", "Tab. S6-S9 NLP macro costs"),
    ("tab_s12_s17_system", "Tab. S10-S17 system costs"),
    ("tab1_comparison", "Tab. 1 / Fig. 4e accelerator comparison"),
    ("tab2_adc", "Tab. 2 ADC comparison"),
    ("fig4d_kws", "Fig. 4d KWS accuracy vs bits"),
    ("fig5c_ptb", "Fig. 5c char-LM BPC vs bits"),
    ("s13_drift", "Supp. S13 drift"),
    ("device_sweep", "repro.core.device preset sweep (drift/redundancy)"),
    ("ir_sweep", "IR-drop correction vs exact nodal solve + bank INL"),
    ("bank_sweep", "threshold-bank sweep (INL/accuracy vs col-tile count)"),
    ("recal_schedule", "serving-lifetime re-calibration schedule sweep"),
    ("fleet_sweep", "fleet serving sweep (N chips x capacity floor)"),
    ("serve_throughput", "offline serving: scan vs bucketed AOT prefill"),
    ("kernel_bench", "kernel microbench"),
    ("kernel_tune", "per-shape kernel block autotune sweep + digests"),
    ("backend_parity", "ref-vs-pallas backend parity + throughput"),
    ("dist_scaling", "repro.dist device-count scaling sweep"),
    ("roofline_report", "dry-run roofline table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    results, failures = {}, []
    for name, desc in MODULES:
        if args.only and args.only != name:
            continue
        print(f"\n##### {name}: {desc} #####", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            results[name] = mod.run(quick=not args.full)
            print(f"##### {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:   # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"##### {name} FAILED: {e}", flush=True)

    print("\n================ benchmark summary ================")
    for name, _ in MODULES:
        if args.only and args.only != name:
            continue
        status = "FAIL" if name in failures else "ok"
        print(f"  {name:22} {status}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
