"""Fleet serving sweep: throughput + admission latency vs N chips × floor.

The orchestration claim (``repro.serve.fleet``): under a recal storm —
every chip's INL over threshold on roughly the same schedule — the
maintenance planner serializes drain windows so fleet capacity never drops
below the configured floor, and the router keeps admission latency bounded
while chips rotate through re-programming.

Each grid cell builds a fleet of ``n`` independently-seeded aged chips
(one ``stressed`` canary) behind a round-robin router, then serves a
deterministic open-loop request stream through an aggressive recal policy.
Recorded per cell:

* ``tokens_per_s``        wall-clock decode throughput (informational —
                          the gate never diffs wall time);
* ``p95_admission_steps`` p95 first-token latency in fleet steps
                          (deterministic: routing, draws, and drain
                          scheduling are all seeded);
* ``min_accepting_frac``  the observed capacity low-water mark — the
                          planner invariant says it never drops below the
                          floor;
* maintenance event counts (requests / drains / reprograms / canary
  warnings) from the fleet event trace;
* per-chip **costed energy efficiency** from ``repro.obs.energy`` —
  tokens-per-joule and TOPS/W under the NL-ADC periphery vs the digital
  LUT baseline, plus their energy ratio (deterministic: token counts ×
  the hwcost price, no wall clock involved).

Writes ``benchmarks/BENCH_fleet.json`` as the recorded baseline for
``benchmarks.fleet_gate``.
"""

from __future__ import annotations

import json
import math
import os
import time

import numpy as np

from repro import configs
from repro.configs.base import AnalogSpec
from repro.serve.engine import Request
from repro.serve.fleet import FleetEngine, FleetPolicy
from repro.serve.lifecycle import RecalPolicy

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_fleet.json")

GRID_QUICK = {"n_chips": (2, 4), "floors": (0.5, 0.75)}
GRID_FULL = {"n_chips": (2, 4, 8), "floors": (0.5, 0.75, 0.9)}

MAX_NEW = 2
REQS_PER_CHIP = 4


def _p95(xs):
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), 95))


def _cell(n_chips: int, floor: float) -> dict:
    cfg = configs.get_smoke("qwen2.5-3b").replace(
        dtype="float32",
        analog=AnalogSpec(enabled=True, mode="infer", device="aged-1day"))
    # every chip out of spec at its first probe: the storm
    pol = RecalPolicy(age_per_step_s=5e4, check_every=2,
                      inl_threshold_lsb=0.05)
    fleet = FleetEngine.build(
        cfg, n_chips,
        policy=FleetPolicy(capacity_floor=floor, router="round-robin"),
        recal=pol, max_batch=1, max_len=48, canary_presets=("stressed",))

    rng = np.random.default_rng(0)
    n_req = REQS_PER_CHIP * n_chips
    uid = 0
    tokens = 0
    min_frac = 1.0
    t0 = time.perf_counter()
    while uid < n_req or any(c.engine.queue or not all(c.engine.slot_free)
                             for c in fleet.chips.values()):
        if uid < n_req:
            fleet.submit(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                max_new_tokens=MAX_NEW))
            uid += 1
        tokens += len(fleet.step())
        min_frac = min(min_frac, fleet.capacity())
        if fleet.step_count > 40 * n_req:       # runaway guard
            break
    wall = time.perf_counter() - t0

    counts = {}
    for ev in fleet.events:
        counts[ev["type"]] = counts.get(ev["type"], 0) + 1
    assert min_frac >= 1.0 - math.ceil(
        n_chips * (1.0 - floor)) / n_chips - 1e-9, (min_frac, n_chips, floor)
    energy = {}
    for cid, rep in sorted(fleet.energy_report().items()):
        energy[cid] = {
            "generated_tokens": rep["generated_tokens"],
            "nladc_tokens_per_joule": round(
                rep["nladc"]["tokens_per_joule"], 1),
            "nladc_tops_per_w": round(rep["nladc"]["tops_per_w"], 2),
            "digital_lut_tops_per_w": round(
                rep["digital_lut"]["tops_per_w"], 2),
            "nladc_vs_digital_energy": round(
                rep.get("nladc_vs_digital_energy", 0.0), 4),
        }
    return {
        "tokens_total": tokens,
        "steps_total": fleet.step_count,
        "tokens_per_s": round(tokens / max(wall, 1e-9), 1),
        "p95_admission_steps": _p95(fleet.admission_latency_steps()),
        "min_accepting_frac": round(min_frac, 4),
        "events": counts,
        "energy": energy,
    }


def run(quick=True):
    grid = GRID_QUICK if quick else GRID_FULL
    cells = {}
    for n in grid["n_chips"]:
        for floor in grid["floors"]:
            key = f"n{n}_floor{floor}"
            print(f"=== fleet sweep: {n} chips, capacity floor {floor} ===")
            cell = _cell(n, floor)
            cells[key] = cell
            print(f"  {cell['tokens_total']} tok in {cell['steps_total']} "
                  f"steps ({cell['tokens_per_s']} tok/s wall)  "
                  f"p95 admission {cell['p95_admission_steps']:.0f} steps  "
                  f"min capacity {cell['min_accepting_frac']:.2f}  "
                  f"events {cell['events']}")
            for cid, e in cell["energy"].items():
                print(f"    {cid}: {e['generated_tokens']} tok, "
                      f"{e['nladc_tokens_per_joule']:.0f} tok/J, "
                      f"nladc {e['nladc_tops_per_w']:.1f} TOPS/W vs "
                      f"digital {e['digital_lut_tops_per_w']:.1f} "
                      f"(energy ratio {e['nladc_vs_digital_energy']:.3f})")

    results = {"quick": quick, "max_new": MAX_NEW,
               "reqs_per_chip": REQS_PER_CHIP, "cells": cells}
    if not quick or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"  baseline written to {OUT_PATH}")
    return results


if __name__ == "__main__":
    run(quick=True)
