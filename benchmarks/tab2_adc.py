"""Tab. 2: ADC comparison — effective latency and AF latency per design."""

from repro.core.hwcost import af_latency_clocks

# (adc_type, resolution, clk MHz, cols/adc, eff latency clocks, af_included)
DESIGNS = [
    ("this work (ramp NL-ADC)", 5, 1000, 1, 32, True),
    ("TED'20 flash", 3, 150, 8, 8, False),
    ("SSCL'20 flash", 1, 140, 8, 8, False),
    ("Nat.El.'19 SAR", 9, 148, 1, 9, False),
    ("Nat.El.'23 CCO", 12, 3300, 1, 128, False),
    ("Nat.El.'22 SAR", 8, 8, 64, 512, False),
    ("JSSC'22 flash", 3, 100, 8, 8, False),
    ("Nature'20 SAR", 8, 20, 4, 32, False),
    ("Science'23 ramp", 8, 200, 1, 256, False),
]


def run(quick=True):
    print("=== Tab. 2: AF latency (clocks), KWS (128 neurons) / "
          "NLP (512 neurons/core) ===")
    out = {}
    for name, res, clk, cols, eff, af in DESIGNS:
        kws = af_latency_clocks(eff, 128, n_cyc=2, k_procs=1,
                                af_included=af)
        nlp = af_latency_clocks(eff, 512, n_cyc=2, k_procs=1,
                                af_included=af)
        print(f"  {name:26} eff {eff:4d}  AF {kws:5d}/{nlp:5d}")
        out[name] = (kws, nlp)
    ours = out["this work (ramp NL-ADC)"]
    others = [v for k, v in out.items() if k != "this work (ramp NL-ADC)"]
    assert all(ours[0] <= o[0] and ours[1] <= o[1] for o in others)
    print("  -> only the NL-ADC integrates the activation: AF latency "
          "32/32 vs 257-1280 elsewhere (paper Tab. 2)")
    return out


if __name__ == "__main__":
    run()
