"""Fig. 3b: read-voltage robustness — in-memory NL-ADC vs conventional ADC."""

import numpy as np

from repro.core.calibration import vread_sweep_inl
from repro.core.nladc import build_ramp


def run(quick=True):
    ramp = build_ramp("sigmoid", 5)
    v = np.linspace(0.15, 0.25, 5)
    inm = vread_sweep_inl(ramp, v, in_memory=True)
    conv = vread_sweep_inl(ramp, v, in_memory=False)
    print("=== Fig. 3b: max INL (LSB) under V_read 0.15-0.25 V ===")
    print(f"{'V_read':>8} {'in-memory':>10} {'conventional':>13}")
    for i, vv in enumerate(v):
        print(f"{vv:8.3f} {inm[i]:10.3f} {conv[i]:13.3f}")
    print(f"max: in-memory {inm.max():.2f} (paper 0.02-0.44), "
          f"conventional {conv.max():.2f} (paper 4.12-5.5)")
    return {"in_memory_max": float(inm.max()),
            "conventional_max": float(conv.max())}


if __name__ == "__main__":
    run()
