"""Tab. S3/S4/S5: KWS macro-level energy/area/latency + derived metrics."""

from repro.core import hwcost as HW

PAPER_S5 = {  # (tput TOPS, power mW, TOPS/W, TOPS/mm2)
    "5b": (0.28, 8.58, 33.04, 115.86),
    "4b": (0.56, 8.43, 66.24, 228.87),
    "3b": (1.08, 8.12, 133.77, 445.64),
    "conv5b": (0.06, 2.58, 23.26, 9.56),
}


def run(quick=True):
    print("=== Tab. S3 (this work, 5-bit NL-ADC, KWS macro) ===")
    m = HW.nladc_macro(72, 128)
    for row in m.table():
        print(f"  {row['name']:20} area {row['area_um2']:9.2f} um2  "
              f"energy {row['energy_pj']:8.2f} pJ")
    print("=== Tab. S5: macro metrics (model | paper) ===")
    out = {}
    for tag, macro in (("5b", HW.kws_macro(5)), ("4b", HW.kws_macro(4)),
                       ("3b", HW.kws_macro(3)),
                       ("conv5b", HW.kws_macro(5, conventional=True))):
        p = PAPER_S5[tag]
        print(f"  {tag:7} tput {macro.throughput_tops:5.2f}|{p[0]:5.2f}  "
              f"power {macro.power_mw:5.2f}|{p[1]:5.2f} mW  "
              f"eff {macro.tops_per_w:6.2f}|{p[2]:6.2f} TOPS/W  "
              f"ae {macro.tops_per_mm2:7.2f}|{p[3]:7.2f} TOPS/mm2")
        out[tag] = dict(tops=macro.throughput_tops,
                        tops_per_w=macro.tops_per_w,
                        tops_per_mm2=macro.tops_per_mm2)
    return out


if __name__ == "__main__":
    run()
