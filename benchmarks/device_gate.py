"""Device-corner CI gate: re-run the smoke device sweep, diff the baseline.

    PYTHONPATH=src python -m benchmarks.device_gate [--tol-acc X] [--tol-inl F]

Runs ``benchmarks.device_sweep`` on the smoke (quick) config and fails —
exit code 1 — when any KWS accuracy point moves more than ``--tol-acc``
(absolute) or any programmed-ramp INL cell moves more than a ``--tol-inl``
fraction (relative) against the committed ``BENCH_device.json``.  This is
the regression tripwire for the whole nonideality pipeline: device presets,
build-stage programming, per-tile aging, Alg. 1 training, and infer-mode
deployment all feed the numbers being diffed.

The sweep is seeded end-to-end, so on one platform the deltas are exactly
zero; the tolerances absorb cross-platform XLA numerics only.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks import device_sweep

BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_device.json")


def _cells(section: dict):
    return {(preset, k): v for preset, rows in section.items()
            for k, v in rows.items()}


def compare(results: dict, baseline: dict, tol_acc: float,
            tol_inl: float) -> list:
    failures = []
    for key, tol, rel in (("ramp_inl_lsb", tol_inl, True),
                          ("kws_accuracy", tol_acc, False),
                          ("kws_accuracy_tiled", tol_acc, False),
                          ("kws_accuracy_banked", tol_acc, False)):
        want_cells = _cells(baseline[key])
        got_cells = _cells(results[key])
        # a sweep corner existing on only one side is itself a gate
        # failure — silently skipping it would defeat the tripwire
        for cell in sorted(set(want_cells) ^ set(got_cells)):
            side = "baseline" if cell in want_cells else "sweep"
            failures.append(
                f"{key} {cell[0]}/{cell[1]}: only present in the {side}; "
                "re-record BENCH_device.json")
        for cell in sorted(set(want_cells) & set(got_cells)):
            want, got = want_cells[cell], got_cells[cell]
            bound = tol * max(abs(want), 1e-9) if rel else tol
            if abs(got - want) > bound:
                failures.append(
                    f"{key} {cell[0]}/{cell[1]}: {got:.4f} vs baseline "
                    f"{want:.4f} (tol {tol:.0%} rel)" if rel else
                    f"{key} {cell[0]}/{cell[1]}: {got:.4f} vs baseline "
                    f"{want:.4f} (tol {tol} abs)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol-acc", type=float, default=0.08,
                    help="absolute accuracy delta allowed per sweep point")
    ap.add_argument("--tol-inl", type=float, default=0.25,
                    help="relative INL delta allowed per sweep cell")
    args = ap.parse_args()

    with open(BASELINE) as f:
        baseline = json.load(f)
    if not baseline.get("quick", False):
        print("[device-gate] note: baseline was recorded with quick=False; "
              "the gate compares a quick run against it")
    results = device_sweep.run(quick=True)

    failures = compare(results, baseline, args.tol_acc, args.tol_inl)
    if failures:
        print(f"\n[device-gate] FAIL — {len(failures)} deltas over "
              "tolerance vs benchmarks/BENCH_device.json:")
        for fail in failures:
            print("  " + fail)
        print("If the shift is intentional, re-record the (quick) "
              "baseline: rm benchmarks/BENCH_device.json && PYTHONPATH=src "
              "python -m benchmarks.run --only device_sweep")
        return 1
    print("\n[device-gate] OK — device corners within tolerance of "
          "BENCH_device.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
