"""IR-drop sweep: first-order correction vs exact nodal solve, per corner.

Two sections, both diffed by ``benchmarks.ir_gate`` in CI:

* **weights**: for each (array size, wire resistance, sourcing) corner,
  the relative MAC error against the exact Kirchhoff nodal solve
  (``repro.core.circuit``) — once for the uncorrected ideal weights
  (what a line-blind pipeline computes) and once for the closed-form
  first-order correction (``crossbar.ir_effective_weights``).  MAC
  cells use the acceptance loading (uniform weights in [-1.5, 1.5],
  the typical hardware-aware-trained range): the correction must win
  by a wide margin everywhere and stay under 1% inside the documented
  validity region (all r <= 2 Ohm at n <= 32; r <= 1 Ohm at n = 64).
  Full-clip Frobenius effective-weight errors (``w_*`` cells, the
  worst-case conductance loading) are recorded as diagnostics but not
  held to the 1% bar — at full clip the drop nearly doubles.
* **bank_inl**: per-col-tile programmed-ramp INL for the IR presets —
  far banks (single sourcing) / middle banks (double) see more wire, so
  the INL profile across banks is the position-dependence fingerprint.

Writes ``benchmarks/BENCH_ir.json`` as the recorded baseline.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import circuit, crossbar
from repro.core.device import get_device
from repro.core.nladc import build_ramp, inl_lsb

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_ir.json")

SIZES = (16, 32, 64)
R_OHMS = (0.5, 1.0, 2.0)
SOURCINGS = ("single", "double")
N_BANKS = 4
IR_PRESETS = ("paper-ir", "stressed-ir")


def in_validity_region(n: int, r_ohm: float) -> bool:
    """Where the first-order correction is contracted to <1% error."""
    return r_ohm <= 2.0 if n <= 32 else r_ohm <= 1.0


def _rel_err(got: np.ndarray, want: np.ndarray) -> float:
    return float(np.linalg.norm(got - want) / np.linalg.norm(want))


def _weight_sweep(quick: bool):
    rng = np.random.default_rng(0)
    out = {}
    for n in SIZES:
        w_mac = rng.uniform(-1.5, 1.5, (n, n))       # acceptance loading
        w_full = rng.uniform(-crossbar.W_CLIP, crossbar.W_CLIP, (n, n))
        x_batch = rng.uniform(-1, 1, (4, n))
        for r in R_OHMS:
            for sourcing in SOURCINGS:
                y_exact = np.stack([
                    circuit.exact_mac_weights(w_mac, x, r, r, sourcing)
                    for x in x_batch])
                w_corr = np.asarray(
                    crossbar.ir_effective_weights(
                        w_mac.astype(np.float32), r, r, sourcing),
                    np.float64)
                exact_full = circuit.exact_effective_weights(
                    w_full, r, r, sourcing)
                corr_full = np.asarray(
                    crossbar.ir_effective_weights(
                        w_full.astype(np.float32), r, r, sourcing),
                    np.float64)
                cell = f"{sourcing}/n{n}/r{r:g}"
                out[cell] = {
                    "uncorrected": round(
                        _rel_err(x_batch @ w_mac, y_exact), 6),
                    "corrected": round(
                        _rel_err(x_batch @ w_corr, y_exact), 6),
                    "w_uncorrected": round(_rel_err(w_full, exact_full), 6),
                    "w_corrected": round(_rel_err(corr_full, exact_full), 6),
                    "in_validity_region": in_validity_region(n, r),
                }
    return out


def _bank_inl_sweep(quick: bool):
    ideal = build_ramp("sigmoid", 5)
    out = {}
    for preset in IR_PRESETS:
        dev = get_device(preset)
        banks = dev.deploy_ramp_bank(ideal, N_BANKS, instance="ir_sweep")
        out[preset] = {
            f"bank{j}": round(inl_lsb(b, ideal)[0], 6)
            for j, b in enumerate(banks)
        }
        out[preset]["worst_bank"] = dev.worst_bank(N_BANKS)
    return out


def run(quick=True):
    results = {
        "quick": quick,
        "weights": _weight_sweep(quick),
        "bank_inl": _bank_inl_sweep(quick),
    }
    for cell, row in results["weights"].items():
        flag = " *" if row["in_validity_region"] else ""
        print(f"  {cell:16} uncorrected {row['uncorrected']:.4f}  "
              f"corrected {row['corrected']:.4f}{flag}")
    for preset, rows in results["bank_inl"].items():
        cells = "  ".join(f"{k}={v}" for k, v in sorted(rows.items())
                          if k.startswith("bank"))
        print(f"  {preset:12} {cells}  (worst={rows['worst_bank']})")
    if not quick or not os.path.exists(OUT_PATH):
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=2)
        print(f"  wrote {OUT_PATH}")
    return results


if __name__ == "__main__":
    run(quick=False)
