"""IR-drop CI gate: re-run the IR sweep, diff the baseline, hold the 1% bar.

    PYTHONPATH=src python -m benchmarks.ir_gate [--tol F] [--max-corrected X]

Runs ``benchmarks.ir_sweep`` and fails (exit 1) when

* any weight-error or bank-INL cell moves more than a ``--tol`` fraction
  (relative) against the committed ``BENCH_ir.json``, or a cell exists on
  only one side;
* any corner **inside the documented validity region** has a corrected
  effective-weight error above ``--max-corrected`` (the 1% acceptance
  bound against the exact nodal solve), or the correction fails to beat
  the uncorrected error at every corner.

The sweep is seeded and host-side float64 throughout, so on one platform
the baseline deltas are exactly zero; ``--tol`` absorbs cross-platform
BLAS/LAPACK numerics only.  The validity bound is absolute and
platform-independent.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks import ir_sweep

BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_ir.json")


def _flat(results: dict):
    cells = {}
    for cell, row in results["weights"].items():
        for k in ("uncorrected", "corrected", "w_uncorrected",
                  "w_corrected"):
            cells[("weights", cell, k)] = row[k]
    for preset, rows in results["bank_inl"].items():
        for k, v in rows.items():
            if k.startswith("bank"):
                cells[("bank_inl", preset, k)] = v
    return cells


def compare(results: dict, baseline: dict, tol: float,
            max_corrected: float) -> list:
    failures = []
    want_cells, got_cells = _flat(baseline), _flat(results)
    for cell in sorted(set(want_cells) ^ set(got_cells)):
        side = "baseline" if cell in want_cells else "sweep"
        failures.append(f"{'/'.join(cell)}: only present in the {side}; "
                        "re-record BENCH_ir.json")
    for cell in sorted(set(want_cells) & set(got_cells)):
        want, got = want_cells[cell], got_cells[cell]
        if abs(got - want) > tol * max(abs(want), 1e-9):
            failures.append(f"{'/'.join(cell)}: {got:.6f} vs baseline "
                            f"{want:.6f} (tol {tol:.0%} rel)")
    # absolute acceptance bars (independent of the recorded baseline)
    for cell, row in results["weights"].items():
        if row["in_validity_region"] and row["corrected"] > max_corrected:
            failures.append(
                f"weights/{cell}: corrected MAC error "
                f"{row['corrected']:.4f} exceeds the {max_corrected:.0%} "
                "validity-region bound vs the exact nodal solve")
        for unc, corr in (("uncorrected", "corrected"),
                          ("w_uncorrected", "w_corrected")):
            if row[corr] >= row[unc]:
                failures.append(
                    f"weights/{cell}: correction ({row[corr]:.4f}) does "
                    f"not beat {unc} ({row[unc]:.4f})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol", type=float, default=0.05,
                    help="relative delta allowed per cell vs the baseline")
    ap.add_argument("--max-corrected", type=float, default=0.01,
                    help="absolute corrected-error bound inside the "
                         "validity region")
    args = ap.parse_args()

    with open(BASELINE) as f:
        baseline = json.load(f)
    results = ir_sweep.run(quick=True)

    failures = compare(results, baseline, args.tol, args.max_corrected)
    if failures:
        print(f"\n[ir-gate] FAIL — {len(failures)} cells out of bounds vs "
              "benchmarks/BENCH_ir.json:")
        for fail in failures:
            print("  " + fail)
        print("If the shift is intentional, re-record the baseline: "
              "rm benchmarks/BENCH_ir.json && PYTHONPATH=src python -m "
              "benchmarks.run --only ir_sweep")
        return 1
    print("\n[ir-gate] OK — IR-drop correction within tolerance of "
          "BENCH_ir.json and under the validity-region bound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
