"""Supp. S11 / Fig. S12: best-of-R redundancy reduces programmed INL."""

import numpy as np

from repro.core.calibration import program_ramp, program_with_redundancy
from repro.core.nladc import build_ramp


def run(quick=True):
    n_chips = 12 if quick else 48
    print("=== Supp. S11: redundancy (best-of-R) mean INL (LSB) ===")
    out = {}
    for name in ("gelu", "swish", "sigmoid"):
        ramp = build_ramp(name, 5)
        rows = {}
        for copies in (1, 2, 4):
            inls = []
            for c in range(n_chips):
                rng = np.random.default_rng(7000 + c)
                if copies == 1:
                    inls.append(program_ramp(ramp, rng).inl()[0])
                else:
                    inls.append(program_with_redundancy(
                        ramp, rng, copies=copies).inl()[0])
            rows[copies] = float(np.mean(inls))
        print(f"{name:8} R=1: {rows[1]:.3f}  R=2: {rows[2]:.3f}  "
              f"R=4: {rows[4]:.3f}")
        out[name] = rows
        assert rows[4] <= rows[1]
    print("(paper Fig. S12: GELU average INL -1.14 -> -0.38 LSB with R=4)")
    return out


if __name__ == "__main__":
    run(quick=False)
