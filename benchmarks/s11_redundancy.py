"""Supp. S11 / Fig. S12: best-of-R redundancy reduces programmed INL.

A thin sweep over ``repro.core.device`` models: one ``paper-infer``-derived
preset per redundancy level (``Redundancy(n_copies=R)``), each chip one
:meth:`DeviceModel.program` call.  Seeded parity with the pre-device-API
``program_ramp`` / ``program_with_redundancy`` sequence is pinned by
``tests/test_device.py``.
"""

import numpy as np

from repro.core.device import Redundancy, get_device
from repro.core.nladc import build_ramp

COPIES = (1, 2, 4)


def run(quick=True):
    n_chips = 12 if quick else 48
    devs = {r: get_device("paper-infer").replace(
        name=f"paper-infer-R{r}", redundancy=Redundancy(n_copies=r))
        for r in COPIES}
    print("=== Supp. S11: redundancy (best-of-R) mean INL (LSB) ===")
    out = {}
    for name in ("gelu", "swish", "sigmoid"):
        ramp = build_ramp(name, 5)
        rows = {}
        for copies, dev in devs.items():
            inls = [dev.program(ramp, np.random.default_rng(7000 + c)).inl()[0]
                    for c in range(n_chips)]
            rows[copies] = float(np.mean(inls))
        print(f"{name:8} R=1: {rows[1]:.3f}  R=2: {rows[2]:.3f}  "
              f"R=4: {rows[4]:.3f}")
        out[name] = rows
        assert rows[4] <= rows[1]
    print("(paper Fig. S12: GELU average INL -1.14 -> -0.38 LSB with R=4)")
    return out


if __name__ == "__main__":
    run(quick=False)
