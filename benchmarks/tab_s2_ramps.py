"""Tab. S1/S2 + Fig. 2d/2e: ramp step tables and SRAM-vs-RRAM cell counts."""

import numpy as np

from repro.core.nladc import build_ramp

PAPER_SUMS = {"sigmoid": (6.992, 58), "softplus": (4.813, 59),
              "tanh": (3.498, 58), "softsign": (8.0, 150),
              "elu": (7.849, 41), "selu": (7.849, 41)}


def run(quick=True):
    print("=== Tab. S2: dV_k sums and SRAM cell counts (5-bit) ===")
    print(f"{'fn':10} {'sum|dV|':>8} {'paper':>7} {'SRAM cells':>10} "
          f"{'paper':>6} {'RRAM cells':>10} {'adv':>6}")
    out = {}
    for name, (psum, pcells) in PAPER_SUMS.items():
        ramp = build_ramp(name, 5)
        steps = np.abs(ramp.steps)
        sram = int(np.round(steps / steps.min()).sum())
        adv = sram / 32.0
        print(f"{name:10} {steps.sum():8.3f} {psum:7.3f} {sram:10d} "
              f"{pcells:6d} {32:10d} {adv:5.2f}x")
        out[name] = dict(sum=float(steps.sum()), sram_cells=sram,
                         advantage=adv)
    # paper claims 1.28x-4.68x advantage band for the 5-bit case
    advs = [v["advantage"] for v in out.values()]
    print(f"advantage band: {min(advs):.2f}x - {max(advs):.2f}x "
          "(paper: 1.28x - 4.68x)")
    return out


if __name__ == "__main__":
    run()
