"""Tab. S10-S17: full-system (LSTM + elementwise tail + FC [+ buffer/NoC])
energy/area/latency for KWS and NLP, ours vs the conventional baseline."""

from repro.core import hwcost as HW

PAPER = {
    # system level, 5-bit: (TOPS/W ours, TOPS/W conv, AE ours, AE conv)
    "kws": (31.33, 21.27, 39.48, 6.41),
    "nlp": (47.9, 44.2, 27.6, 4.2),     # conv = k=8 column of Tab. S17
}


def run(quick=True):
    out = {}
    print("=== Tab. S12 (KWS system) and Tab. S17 (NLP system) ===")
    kws_o, kws_c = HW.kws_system(5), HW.kws_system(5, conventional=True)
    nlp_o = HW.nlp_system(5)
    nlp_c = HW.nlp_system(5, conventional=True, k_procs=8)
    for tag, (o, c) in (("kws", (kws_o, kws_c)), ("nlp", (nlp_o, nlp_c))):
        p = PAPER[tag]
        print(f"  {tag}: eff {o.tops_per_w:6.2f}|{p[0]:6.2f} vs conv "
              f"{c.tops_per_w:6.2f}|{p[1]:6.2f} TOPS/W;  "
              f"ae {o.tops_per_mm2:6.2f}|{p[2]:6.2f} vs conv "
              f"{c.tops_per_mm2:6.2f}|{p[3]:6.2f} TOPS/mm2")
        out[tag] = dict(ours_eff=o.tops_per_w, conv_eff=c.tops_per_w,
                        ours_ae=o.tops_per_mm2, conv_ae=c.tops_per_mm2)
    print("=== Tab. S13: energy-efficiency by subsystem (KWS 5-bit) ===")
    # NL-processing = NL-ADC array + integrator + S&H + comparators
    ours_macro = HW.nladc_macro(72, 128)
    conv_macro = HW.conventional_macro(72, 128)
    nl_ours = sum(m.energy_pj for m in ours_macro.modules
                  if m.name in ("NL-ADC array", "Comparator"))
    nl_ours += ours_macro.modules[3].energy_pj / 129  # 1 of 129 integrators
    nl_conv = sum(m.energy_pj for m in conv_macro.modules
                  if m.name in ("Ramp-ADC", "Processor"))
    n_ops_nl = 128 * 2  # one activation per column counted as 2 ops
    print(f"  NL-processing: ours {n_ops_nl / nl_ours:5.2f} TOPS/W "
          f"(paper 3.6), conventional {n_ops_nl / nl_conv:5.2f} "
          f"(paper 0.3)")
    out["nl_processing"] = dict(ours=n_ops_nl / nl_ours,
                                conv=n_ops_nl / nl_conv)
    return out


if __name__ == "__main__":
    run()
